"""Import-or-shim for `hypothesis`.

The container this repo's tier-1 suite runs in does not ship `hypothesis`
(and installing packages is off-limits), which used to kill collection of
three test modules with ImportError. Test modules import `given`/`settings`/
`st` from here instead: when the real package is available it is used
verbatim; otherwise a deterministic single-example fallback runs each
property test once at the midpoint of every strategy's range — strictly
weaker than real property testing, but the assertions still execute.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    import functools
    import inspect

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, example):
            self.example = example

    class _Strategies:
        @staticmethod
        def floats(lo: float, hi: float, **_kw) -> _Strategy:
            return _Strategy(lo + (hi - lo) / 2.0)

        @staticmethod
        def integers(lo: int, hi: int, **_kw) -> _Strategy:
            return _Strategy(lo + (hi - lo) // 2)

        @staticmethod
        def booleans() -> _Strategy:
            return _Strategy(True)

        @staticmethod
        def sampled_from(seq) -> _Strategy:
            return _Strategy(next(iter(seq)))

    st = _Strategies()

    def given(*strategies: _Strategy, **kw_strategies: _Strategy):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                drawn = tuple(s.example for s in strategies)
                drawn_kw = {k: s.example for k, s in kw_strategies.items()}
                return fn(*args, *drawn, **kwargs, **drawn_kw)

            # hide the drawn parameters from pytest's fixture resolution
            # (functools.wraps would otherwise expose them via __wrapped__)
            del wrapper.__wrapped__
            sig = inspect.signature(fn)
            params = list(sig.parameters.values())
            keep = params[:len(params) - len(strategies)]
            keep = [p for p in keep if p.name not in kw_strategies]
            wrapper.__signature__ = sig.replace(parameters=keep)
            return wrapper

        return deco

    def settings(**_kw):
        def deco(fn):
            return fn

        return deco
