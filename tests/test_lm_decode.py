"""LM decode-path contracts: prefill/decode_step parity, EOS and ragged
finish, the engine's continuous batching, and the per-(request, token)
PRNG reproducibility the semantic cache's bit-identity rests on."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import lm
from repro.serve.engine import Engine, Request


@pytest.fixture(scope="module")
def stack():
    cfg = configs.get("tinyllama-1.1b", smoke=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


class TestDecodeParity:
    def test_prefill_matches_step_by_step_decode(self, stack):
        """The last-position logits of one whole-prompt prefill must match
        feeding the same prompt token-by-token through decode_step — the
        KV/positional bookkeeping agreeing between the two entry points."""
        cfg, params = stack
        rng = np.random.RandomState(0)
        prompt = rng.randint(0, cfg.vocab, size=12).astype(np.int32)
        max_len = 32

        full_logits, _ = lm.prefill(params, cfg, prompt[None, :],
                                    max_len=max_len)

        # seed the cache with the first token, then step the rest
        step_logits, cache = lm.prefill(params, cfg, prompt[None, :1],
                                        max_len=max_len)
        for t in prompt[1:]:
            step_logits3, cache = lm.decode_step(
                params, cfg, jnp.full((1, 1), int(t), jnp.int32), cache)
            step_logits = step_logits3[:, 0]
        np.testing.assert_allclose(np.asarray(full_logits),
                                   np.asarray(step_logits),
                                   rtol=2e-3, atol=2e-3)

    def test_parity_across_prefill_splits(self, stack):
        """Splitting the prompt prefill/decode at any point lands on the
        same distribution (argmax-stable)."""
        cfg, params = stack
        rng = np.random.RandomState(1)
        prompt = rng.randint(0, cfg.vocab, size=10).astype(np.int32)
        ref, _ = lm.prefill(params, cfg, prompt[None, :], max_len=32)
        for split in (4, 7):
            logits, cache = lm.prefill(params, cfg, prompt[None, :split],
                                       max_len=32)
            for t in prompt[split:]:
                logits3, cache = lm.decode_step(
                    params, cfg, jnp.full((1, 1), int(t), jnp.int32), cache)
                logits = logits3[:, 0]
            assert int(jnp.argmax(ref)) == int(jnp.argmax(logits))


class TestEosAndRaggedFinish:
    def test_eos_truncates_generation(self, stack):
        cfg, params = stack
        prompt = (np.arange(6) % cfg.vocab).astype(np.int32)
        eng = Engine(cfg, params, batch_size=2, max_len=64)
        (ref,) = eng.generate([Request(prompt=prompt, max_new_tokens=8)])
        assert len(ref.out) == 8
        eos = ref.out[3]  # force EOS at the 4th generated token
        eng2 = Engine(cfg, params, batch_size=2, max_len=64)
        (r,) = eng2.generate([Request(prompt=prompt, max_new_tokens=8,
                                      eos_id=eos)])
        assert r.done
        assert r.out == ref.out[:ref.out.index(eos) + 1]
        assert r.out[-1] == eos and len(r.out) <= 8

    def test_ragged_finish_and_continuous_joins(self, stack):
        """Slots finish at their own budgets; a finished slot admits the
        next queued request mid-batch (joins > 0), and the stats stay
        honest: every request served, occupancy in (0, 1]."""
        cfg, params = stack
        rng = np.random.RandomState(2)
        eng = Engine(cfg, params, batch_size=3, max_len=64)
        reqs = [Request(prompt=rng.randint(0, cfg.vocab, size=5 + i),
                        max_new_tokens=3 + 2 * i) for i in range(6)]
        out = eng.generate(reqs)
        for i, r in enumerate(out):
            assert r.done and len(r.out) == 3 + 2 * i
            assert all(0 <= t < cfg.vocab for t in r.out)
        s = eng.stats
        assert s.requests == 6
        assert s.joins >= 1  # continuous batching actually happened
        assert s.groups < -(-6 // 3) + s.joins  # joins saved group starts
        assert 0.0 < s.occupancy <= 1.0
        assert s.slot_steps <= s.decode_steps * s.slots
        d = s.as_dict()
        assert d["joins"] == s.joins and d["occupancy"] == round(
            s.occupancy, 4)

    def test_max_len_truncates_mid_flight(self, stack):
        cfg, params = stack
        prompt = (np.arange(8) % cfg.vocab).astype(np.int32)
        eng = Engine(cfg, params, batch_size=1, max_len=10)
        (r,) = eng.generate([Request(prompt=prompt, max_new_tokens=16)])
        assert r.done and len(r.out) <= 16  # ran out of cache room


class TestSamplingReproducibility:
    """temperature > 0: the fold_in(fold_in(key, rid), t) contract —
    a request's sampled tokens cannot depend on batch composition."""

    PLEN, MAX_NEW = 5, 6

    def _reqs(self, cfg, n):
        rng = np.random.RandomState(7)
        return [Request(prompt=rng.randint(0, cfg.vocab, size=self.PLEN),
                        max_new_tokens=self.MAX_NEW) for _ in range(n)]

    def _engine(self, cfg, params, batch_size):
        # max_len = PLEN + MAX_NEW - 1 makes _can_join always fail: every
        # request runs in a fresh same-shape group, so logits see no pad
        # variation and the outputs must be EXACTLY batch-size invariant
        return Engine(cfg, params, batch_size=batch_size,
                      max_len=self.PLEN + self.MAX_NEW - 1,
                      temperature=0.7, seed=0)

    def test_outputs_invariant_across_batch_sizes(self, stack):
        cfg, params = stack
        outs = {}
        for b in (1, 2, 4):
            reqs = self._reqs(cfg, 4)
            self._engine(cfg, params, b).generate(reqs)
            outs[b] = [r.out for r in reqs]
        assert outs[1] == outs[2] == outs[4]

    def test_stream_keyed_by_rid_not_slot(self, stack):
        """Serving a request alone draws the same tokens as serving it
        alongside neighbours — pin rids so the streams line up."""
        cfg, params = stack
        reqs = self._reqs(cfg, 3)
        self._engine(cfg, params, 4).generate(reqs)
        solo = self._reqs(cfg, 3)[1]
        solo.rid = 1  # replay request 1's stream, alone in the batch
        self._engine(cfg, params, 1).generate([solo])
        assert solo.out == reqs[1].out

    def test_greedy_ignores_temperature_machinery(self, stack):
        cfg, params = stack
        reqs = self._reqs(cfg, 2)
        Engine(cfg, params, batch_size=2, max_len=32).generate(reqs)
        again = self._reqs(cfg, 2)
        Engine(cfg, params, batch_size=2, max_len=32).generate(again)
        assert [r.out for r in reqs] == [r.out for r in again]
