"""The resident serving mega-kernel + symmetric chunked coverage (PR 8).

Four contracts:

  * `MatchEngine.classify_serve` on the kernel backend — the ONE-pallas_call
    gather -> binarize -> match -> per-class max -> WTA -> windowed margin ->
    escalation-mask path — is bit-identical to the jnp reference oracle AND
    to the pre-megakernel composition (``serve_fusion="compose"``), for both
    methods, at resident AND class-chunked bank sizes;
  * the tick really is a single dispatch: the traced jaxpr contains exactly
    one pallas_call, with no jnp epilogue, below and above MAX_FUSED_ROWS;
  * the similarity method now has the same single-dispatch chunked coverage
    as feature_count ((1100, 2) and (300, 8) both exceed the fused budget);
  * "auto" backend routing uses per-method tiny cutoffs
    (`repro.match.tiny_cutoff`) from the measured reference/kernel
    crossovers, and the autotuner cache separates interpreted from compiled
    timings (v2 ``+interp`` keys).

Similarity parity notes: every operand is a dyadic rational (n/4, n/8) and
every query is exactly representable, so the kernel's per-chunk f32
accumulation and the oracle's full-axis sums are both exact — bit-equality
is well-defined, not luck.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import match
from repro.core.templates import TemplateBank
from repro.kernels import layout, tuning

N = 64
SLOT_TABLE = 8


def _dyadic(rng, shape, lo=-8, hi=9, denom=4.0):
    return jnp.asarray((rng.integers(lo, hi, size=shape) / denom
                        ).astype(np.float32))


def _serve_inputs(seed, b, c, k, n=N):
    rng = np.random.default_rng(seed)
    feats = _dyadic(rng, (b, n))
    thr_table = _dyadic(rng, (SLOT_TABLE, n), -4, 5)
    slot = jnp.asarray(rng.integers(0, SLOT_TABLE, size=b).astype(np.int32))
    lo_w = _dyadic(rng, (c, k, n), -8, 1)
    hi_w = lo_w + _dyadic(rng, (c, k, n), 0, 9)
    valid = jnp.asarray(rng.random((c, k)) > 0.2)
    bank = TemplateBank(templates=(lo_w > 0).astype(jnp.float32),
                        lower=lo_w, upper=hi_w, valid=valid,
                        thresholds=jnp.zeros((n,), jnp.float32))
    lo = jnp.asarray(rng.integers(0, max(c - 4, 1), size=b).astype(np.int32))
    hi = jnp.minimum(lo + rng.integers(1, c + 1, size=b), c).astype(jnp.int32)
    hi = hi.at[0].set(lo[0])  # empty window: pred 0 / margin 0 / no escalate
    return feats, thr_table, slot, bank, lo, hi


def _eng(method, backend, serve_fusion="mega"):
    return match.engine_from_config(match.EngineConfig(
        method=method, backend=backend, serve_fusion=serve_fusion))


# (c, k) resident in the fused budget, and two chunked shapes past it
RESIDENT = (12, 4)
CHUNKED = [(1100, 2), (300, 8)]


class TestMegaKernelParity:
    @pytest.mark.parametrize("method", ["feature_count", "similarity"])
    @pytest.mark.parametrize("c,k", [RESIDENT] + CHUNKED)
    def test_bit_identical_to_oracle_and_compose(self, method, c, k):
        feats, thr_table, slot, bank, lo, hi = _serve_inputs(c + k, 16, c, k)
        ref = _eng(method, "reference")
        # per-row taus straddle each oracle margin (above on even rows,
        # below on odd): the escalation set is non-trivial by construction
        margins = ref.classify_serve(feats, thr_table, slot, bank, lo, hi)[2]
        sign = jnp.where(jnp.arange(16) % 2 == 0, 0.5, -0.5)
        tau = (margins + sign).astype(jnp.float32)

        r = ref.classify_serve(feats, thr_table, slot, bank, lo, hi, tau)
        k_ = _eng(method, "kernel").classify_serve(
            feats, thr_table, slot, bank, lo, hi, tau)
        comp = _eng(method, "kernel", "compose").classify_serve(
            feats, thr_table, slot, bank, lo, hi, tau)
        for name, i in zip(("pred", "per_class", "margin", "escalate"),
                           range(4)):
            np.testing.assert_array_equal(
                np.asarray(r[i]), np.asarray(k_[i]), err_msg=f"mega {name}")
            np.testing.assert_array_equal(
                np.asarray(r[i]), np.asarray(comp[i]),
                err_msg=f"compose {name}")
        esc = np.asarray(r[3])
        assert esc.any() and not esc.all()  # mask exercised both ways
        assert int(r[0][0]) == 0 and float(r[2][0]) == 0.0  # empty window
        assert bool(esc[0]) == (0.0 < float(tau[0]))

    @pytest.mark.parametrize("method", ["feature_count", "similarity"])
    @pytest.mark.parametrize("c,k", [RESIDENT, CHUNKED[0]])
    def test_tick_is_one_pallas_call(self, method, c, k):
        feats, thr_table, slot, bank, lo, hi = _serve_inputs(7, 8, c, k)
        tau = jnp.zeros((8,), jnp.float32)
        eng = _eng(method, "kernel")
        jaxpr = str(jax.make_jaxpr(lambda *a: eng.classify_serve(*a))(
            feats, thr_table, slot, bank, lo, hi, tau))
        assert jaxpr.count("pallas_call") == 1

    def test_default_tau_never_escalates(self):
        feats, thr_table, slot, bank, lo, hi = _serve_inputs(3, 8, *RESIDENT)
        out = _eng("feature_count", "kernel").classify_serve(
            feats, thr_table, slot, bank, lo, hi)
        assert not np.asarray(out[3]).any()


class TestChunkedSimilarityCoverage:
    """The similarity method's symmetric single-dispatch chunked path."""

    @pytest.mark.parametrize("c,k", CHUNKED)
    def test_margin_parity_past_fused_budget(self, c, k):
        assert k * layout.padded_classes(c) > match.MAX_FUSED_ROWS
        feats, _, _, bank, lo, hi = _serve_inputs(c, 16, c, k)
        ker = _eng("similarity", "kernel")
        ref = _eng("similarity", "reference")
        got = ker.classify_features_margin(feats, bank, lo, hi)
        want = ref.classify_features_margin(feats, bank, lo, hi)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))

    @pytest.mark.parametrize("c,k", CHUNKED)
    def test_classify_features_single_dispatch_parity(self, c, k):
        feats, _, _, bank, _, _ = _serve_inputs(c + 1, 8, c, k)
        ker = _eng("similarity", "kernel")
        got = ker.classify_features(feats, bank)
        want = _eng("similarity", "reference").classify_features(feats, bank)
        np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
        np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]))
        jaxpr = str(jax.make_jaxpr(
            lambda f: ker.classify_features(f, bank))(feats))
        assert jaxpr.count("pallas_call") == 1


class TestTinyCutoffRouting:
    def test_per_method_cutoffs(self):
        assert match.tiny_cutoff("feature_count") == match.TINY_ELEMENTS
        assert match.tiny_cutoff("similarity") == \
            match.TINY_ELEMENTS_SIMILARITY
        assert match.TINY_ELEMENTS_SIMILARITY > match.TINY_ELEMENTS

    def test_auto_routes_tiny_shapes_to_reference(self):
        # an element count between the two cutoffs: the VPU-bound similarity
        # kernel still loses to jnp there, the MXU match kernel already wins
        mid = (match.TINY_ELEMENTS + match.TINY_ELEMENTS_SIMILARITY) // 2
        fc = match.engine_from_config(match.EngineConfig(
            method="feature_count", backend="auto"))
        sim = match.engine_from_config(match.EngineConfig(
            method="similarity", backend="auto"))
        assert isinstance(fc.backend(match.TINY_ELEMENTS - 1),
                          match.ReferenceBackend)
        assert isinstance(fc.backend(mid), match.KernelBackend)
        assert isinstance(sim.backend(mid), match.ReferenceBackend)
        assert isinstance(sim.backend(match.TINY_ELEMENTS_SIMILARITY),
                          match.KernelBackend)


class TestTuningCacheKeys:
    def test_interp_token_separates_cpu_populations(self):
        key = tuning.entry_key("acam_match", (8, 8, 8), jnp.float32)
        if tuning.interpret_mode():
            assert "+interp" in key
        else:
            assert "+interp" not in key
        # explicit device strings are taken verbatim (offline tuning for
        # another platform never inherits this process's interpret state)
        assert "+interp" not in tuning.entry_key("acam_match", (8, 8, 8),
                                                 jnp.float32, device="tpu")

    def test_v1_caches_discarded(self, tmp_path, monkeypatch):
        import json
        path = tmp_path / "blocks.json"
        path.write_text(json.dumps({
            "version": 1,
            "entries": {"acam_match|cpu|b8_m8_n8|float32":
                        {"block": [256, 256, 1024], "us": 1.0}}}))
        monkeypatch.setenv("REPRO_TUNING_CACHE", str(path))
        tuning.clear_cache_for_tests()
        try:
            assert tuning.get_block("acam_match", (8, 8, 8), jnp.float32) \
                == tuning.default_block("acam_match")
        finally:
            tuning.clear_cache_for_tests()


class TestServeFusionConfig:
    def test_validate_rejects_unknown_mode(self):
        with pytest.raises(ValueError, match="serve_fusion"):
            match.MatchEngine(match.EngineConfig(serve_fusion="hyper"))

    def test_modes_are_distinct_jit_keys(self):
        a = match.EngineConfig(serve_fusion="mega")
        b = match.EngineConfig(serve_fusion="compose")
        assert a != b and hash(a) != hash(b)
