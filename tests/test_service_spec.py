"""ServiceSpec + the HybridService control plane (PR 5).

Four layers of coverage:

  * `ServiceSpec` as a value object: hashable, JSON-round-trippable across
    every backend (`spec == ServiceSpec.from_json(spec.to_json())`), with
    eager cross-field `validate()` (device sharding under "global"
    sigma_program noise, capacity vs shards, tau units vs the matchline
    cap);
  * the legacy shims: `ACAMService(...)` keywords delegate to the spec
    path unchanged, and the mesh-ordering footgun now warns loudly
    (bank_shards=None with no mesh installed -> silent 1);
  * live transitions (in-process): `reconfigure` resharding 1 -> 2 -> 1 on
    a populated registry with bit-identical served results and ZERO tenant
    re-registrations, live backend swap, tau retune, frozen-field guard,
    and `TemplateBankRegistry.reshard` re-packing direct;
  * forced 2x2 CPU mesh (subprocess): the spec path owns the mesh end to
    end — boot at bank_shards=1, reconfigure to 2 (sharded dispatch, one
    per tick), back to 1, bit-identical preds/margins/escalations at every
    step; and the per-shard device-noise semantics: a bank-sharded
    `device_noise="per_shard"` run equals the replicated S-array emulation
    (`program_bank(..., bank_shards=S)`) bit for bit.
"""
import os
import subprocess
import sys
import textwrap
import warnings

import numpy as np
import pytest

from repro.core.acam import ACAMConfig
from repro.distributed import context
from repro.match.config import EngineConfig
from repro.serve.acam_service import (ACAMService, ClassifyRequest,
                                      ServiceConfig, make_synthetic_tenant,
                                      sample_tenant_queries)
from repro.serve.control import HybridService, ReconfigureError
from repro.serve.registry import TemplateBankRegistry
from repro.serve.spec import (CascadeSpec, MeshSpec, RegistrySpec,
                              SchedulerSpec, ServiceSpec)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
N = 64


def _spec(backend="reference", *, bank_shards=1, slots=16, tau=6.0,
          install=False, **engine_kw):
    return ServiceSpec(
        registry=RegistrySpec(num_features=N, initial_classes=256),
        engine=EngineConfig(backend=backend, margin=True, **engine_kw),
        mesh=MeshSpec(bank_shards=bank_shards, install=install),
        scheduler=SchedulerSpec(slots=slots),
        cascade=CascadeSpec(tau=tau, tau_units="count"),
    )


def _populate(svc, classes=(40, 40, 40, 40)):
    protos = {}
    for t, c in enumerate(classes):
        bank, head, p = make_synthetic_tenant(1000 + 17 * t, num_classes=c,
                                              num_features=N)
        svc.register_tenant(f"t{t}", bank, head=head)
        protos[f"t{t}"] = p
    return protos


def _requests(protos, per_tenant=30, noise=0.9):
    reqs = []
    for i, (tid, p) in enumerate(sorted(protos.items())):
        f, _ = sample_tenant_queries(7 + i, p, per_tenant, noise=noise)
        reqs += [ClassifyRequest(tid, f[j]) for j in range(per_tenant)]
    return reqs


def _signature(responses):
    return [(r.tenant_id, r.pred, r.escalated, round(r.margin, 6))
            for r in responses]


@pytest.fixture
def no_mesh():
    """Run with a cleared mesh context (REPRO_FORCE_MESH installs one
    session-wide); restores whatever was installed afterwards."""
    saved_axes, saved_mesh = context.get(), context.get_mesh()
    context.clear()
    try:
        yield
    finally:
        context.clear()
        if saved_axes is not None:
            context.set_mesh_axes(saved_axes.dp, saved_axes.model,
                                  saved_mesh)


class TestServiceSpecValue:
    @pytest.mark.parametrize("backend", ("auto", "reference", "kernel",
                                         "device"))
    def test_json_roundtrip_every_backend(self, backend):
        device = ACAMConfig(cell="3T1R", sigma_program=0.15) \
            if backend == "device" else None
        spec = ServiceSpec(
            registry=RegistrySpec(num_features=128, k_max=3,
                                  initial_classes=192),
            engine=EngineConfig(method="similarity", alpha=0.5,
                                backend=backend, block=(8, 16, 32),
                                margin=True, device=device, seed=11,
                                device_noise="per_shard"),
            mesh=MeshSpec(bank_shards=2, data_axis="dp", model_axis="mp",
                          install=False),
            scheduler=SchedulerSpec(slots=7),
            cascade=CascadeSpec(tau=0.25, tau_units="fraction",
                                max_queue=99, frontend_sparsity=0.5),
        )
        again = ServiceSpec.from_json(spec.to_json())
        assert again == spec
        assert hash(again) == hash(spec)
        assert isinstance(again.engine.block, tuple)
        if device is not None:
            assert isinstance(again.engine.device, ACAMConfig)

    def test_defaults_roundtrip_and_validate(self):
        spec = ServiceSpec()
        assert ServiceSpec.from_json(spec.to_json()) == spec
        assert spec.validate() is spec

    def test_file_roundtrip(self, tmp_path):
        spec = _spec("kernel", bank_shards=2)
        path = tmp_path / "service.json"
        path.write_text(spec.to_json())
        assert ServiceSpec.from_file(str(path)) == spec

    def test_validate_device_global_noise_shard_conflict(self):
        bad = _spec("device", bank_shards=2,
                    device=ACAMConfig(sigma_program=0.1))
        with pytest.raises(ValueError, match="per_shard"):
            bad.validate()
        # per-shard programming keys lift the refusal
        bad._replace(engine=bad.engine._replace(
            device_noise="per_shard")).validate()
        # ...as does an ideal array
        _spec("device", bank_shards=2,
              device=ACAMConfig(sigma_program=0.0)).validate()

    def test_validate_capacity_vs_shards(self):
        bad = _spec()
        bad = bad._replace(registry=bad.registry._replace(
            initial_classes=120), mesh=bad.mesh._replace(bank_shards=2))
        with pytest.raises(ValueError, match="whole"):
            bad.validate()

    def test_validate_misc_conflicts(self):
        with pytest.raises(ValueError, match="tau_units"):
            _spec()._replace(cascade=CascadeSpec(
                tau_units="volts")).validate()
        with pytest.raises(ValueError, match="fraction"):
            _spec()._replace(cascade=CascadeSpec(
                tau=8.0, tau_units="fraction")).validate()
        with pytest.raises(ValueError, match="max_queue"):
            _spec()._replace(cascade=CascadeSpec(
                max_queue=0)).validate()
        with pytest.raises(ValueError, match="method"):
            _spec()._replace(engine=EngineConfig(
                method="cosine")).validate()
        with pytest.raises(ValueError, match="axes"):
            _spec()._replace(mesh=MeshSpec(data_axis="x",
                                           model_axis="x")).validate()

    def test_tau_scale_explicit_units(self):
        # digital feature-count margins are match counts: no conversion
        assert _spec("kernel").tau_scale() == 1.0
        # device senses matchline fractions: count taus divide by N
        assert _spec("device").tau_scale() == pytest.approx(1.0 / N)
        # fraction taus serve the device backend unconverted
        frac = _spec("device")._replace(
            cascade=CascadeSpec(tau=0.1, tau_units="fraction"))
        assert frac.tau_scale() == 1.0
        # ...and scale UP to counts for the digital backends
        frac_k = _spec("kernel")._replace(
            cascade=CascadeSpec(tau=0.1, tau_units="fraction"))
        assert frac_k.tau_scale() == pytest.approx(float(N))
        # similarity margins live in [0, 1] whatever the backend
        sim = _spec("kernel", method="similarity")
        assert sim.native_tau_units == "fraction"


class TestLegacyShims:
    def test_legacy_constructor_delegates_to_spec(self, no_mesh):
        svc = ACAMService(N, config=ServiceConfig(slots=8, margin_tau=5.0),
                          backend="reference", bank_shards=1)
        assert svc.spec.engine.backend == "reference"
        assert svc.spec.scheduler.slots == 8
        assert svc.spec.cascade == CascadeSpec(tau=5.0, tau_units="count")
        assert svc.spec.mesh == MeshSpec(bank_shards=1, install=False)
        assert svc.config.margin_tau == 5.0  # legacy view preserved
        assert svc.scheduler.method == "feature_count"

    def test_silent_bank_shards_warns(self, no_mesh):
        """Satellite regression: bank_shards=None with no mesh installed
        used to silently resolve to 1 — now it says so, loudly."""
        with pytest.warns(UserWarning, match="silently resolves to 1"):
            svc = ACAMService(N)
        assert svc.registry.bank_shards == 1

    def test_no_warning_with_mesh_or_explicit_shards(self, no_mesh):
        import jax

        with warnings.catch_warnings():
            warnings.simplefilter("error")
            ACAMService(N, bank_shards=1)  # explicit: intent is clear
            mesh = jax.make_mesh((1, 1), ("data", "model"))
            context.set_mesh_axes("data", "model", mesh)
            ACAMService(N)  # mesh installed: inference is well-defined

    def test_from_spec_makes_the_footgun_impossible(self, no_mesh):
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            svc = HybridService.from_spec(_spec())
        assert svc.registry.bank_shards == 1

    def test_device_tau_rescale_via_spec(self, no_mesh):
        svc = ACAMService(N, config=ServiceConfig(margin_tau=8.0),
                          backend="device", bank_shards=1)
        bank, head, _ = make_synthetic_tenant(3, num_classes=4,
                                              num_features=N)
        svc.register_tenant("t", bank, head=head)
        assert svc._tenants["t"].margin_tau == pytest.approx(8.0 / N)


class TestRegistryReshard:
    def test_repack_moves_straddlers_and_preserves_rows(self):
        reg = TemplateBankRegistry(N, class_bucket=16, initial_classes=256,
                                   bank_shards=1)
        banks = {}
        for t in range(3):  # 48-row runs at 0, 48, 96 — the third straddles
            bank, _, _ = make_synthetic_tenant(400 + t, num_classes=40,
                                               num_features=N)
            reg.register(f"t{t}", bank)
            banks[f"t{t}"] = bank
        before = {t: reg.get(t) for t in banks}
        moved = reg.reshard(2)
        assert moved >= 1  # t2 ([96, 144)) must hop the row-128 boundary
        assert reg.bank_shards == 2
        rps = reg.rows_per_shard
        for t, old in before.items():
            e = reg.get(t)
            assert e.offset // rps == (e.offset + e.c_bucket - 1) // rps
            assert (e.slot, e.num_classes, e.k, e.valid_rows) == \
                (old.slot, old.num_classes, old.k, old.valid_rows)
            # template rows moved bit-for-bit
            sb = reg.device_bank()
            np.testing.assert_array_equal(
                np.asarray(sb.templates[e.offset:e.offset + e.num_classes,
                                        :e.k]),
                np.asarray(banks[t].templates))

    def test_reshard_grows_capacity_when_fragmented(self):
        reg = TemplateBankRegistry(N, class_bucket=16, initial_classes=128,
                                   bank_shards=1)
        for t in range(2):  # two 48-row runs: 96 of 128 rows used
            bank, _, _ = make_synthetic_tenant(500 + t, num_classes=48,
                                               num_features=N)
            reg.register(f"t{t}", bank)
        reg.reshard(2)  # 64-row shards hold one 48-row run each
        assert reg.capacity_classes == 128
        reg.reshard(4)  # 32-row shards hold NO 48-row run: must grow
        assert reg.capacity_classes == 256
        assert reg.rows_per_shard == 64
        rps = reg.rows_per_shard
        for t in ("t0", "t1"):
            e = reg.get(t)
            assert e.offset // rps == (e.offset + e.c_bucket - 1) // rps

    def test_reshard_noop_and_validation(self):
        reg = TemplateBankRegistry(N, bank_shards=2, initial_classes=128)
        assert reg.reshard(2) == 0
        with pytest.raises(ValueError):
            reg.reshard(0)


class TestReconfigure:
    def _boot(self):
        svc = HybridService.from_spec(_spec())
        protos = _populate(svc)
        reqs = _requests(protos)
        return svc, reqs

    def test_live_reshard_1_2_1_bit_identity(self, no_mesh):
        """The acceptance core (replicated execution; the subprocess test
        repeats it under a real sharded mesh): re-packed placements serve
        bit-identical results with zero re-registrations."""
        svc, reqs = self._boot()
        base = _signature(svc.serve(reqs))
        assert any(s[2] for s in base) and any(not s[2] for s in base)

        registered = {"n": 0}
        orig = TemplateBankRegistry.register

        def counting(self, *a, **kw):
            registered["n"] += 1
            return orig(self, *a, **kw)

        TemplateBankRegistry.register = counting
        try:
            report = svc.reconfigure(svc.spec._replace(
                mesh=svc.spec.mesh._replace(bank_shards=2)))
        finally:
            TemplateBankRegistry.register = orig
        assert registered["n"] == 0
        assert report.tenants_moved >= 1
        assert svc.registry.bank_shards == 2
        assert _signature(svc.serve(reqs)) == base

        svc.reconfigure(svc.spec._replace(
            mesh=svc.spec.mesh._replace(bank_shards=1)))
        assert svc.registry.bank_shards == 1
        assert _signature(svc.serve(reqs)) == base

    def test_reconfigure_drains_pending_under_old_config(self, no_mesh):
        svc, reqs = self._boot()
        for r in reqs[:10]:
            svc.submit(r)
        report = svc.reconfigure(svc.spec._replace(
            mesh=svc.spec.mesh._replace(bank_shards=2)))
        assert len(report.drained) == 10
        assert svc.scheduler.qsize == 0
        assert report.downtime_s > 0

    def test_live_backend_swap_parity_and_retrace(self, no_mesh):
        from repro.serve import scheduler as sched_lib

        svc, reqs = self._boot()
        base = _signature(svc.serve(reqs))
        size0 = sched_lib._batched_classify._cache_size()
        report = svc.reconfigure(svc.spec._replace(
            engine=svc.spec.engine._replace(backend="kernel")))
        assert any("engine" in a for a in report.actions)
        assert _signature(svc.serve(reqs)) == base  # kernel == reference
        # the new EngineConfig is a fresh static jit key: exactly one new
        # trace, not a silent replay of the reference executable
        assert sched_lib._batched_classify._cache_size() == size0 + 1

    def test_tau_retune_moves_the_cascade(self, no_mesh):
        svc, reqs = self._boot()
        base = _signature(svc.serve(reqs))
        svc.reconfigure(svc.spec._replace(cascade=CascadeSpec(
            tau=float(N), tau_units="count")))
        # margins cap below N: every headed request now escalates
        everything = _signature(svc.serve(reqs))
        assert all(s[2] for s in everything)
        # decisions and margins themselves are untouched by the tau move
        assert [(s[0], s[1], s[3]) for s in everything] == \
            [(s[0], s[1], s[3]) for s in base]

    def test_slots_change_rebuilds_scheduler(self, no_mesh):
        svc, reqs = self._boot()
        base = _signature(svc.serve(reqs))
        svc.reconfigure(svc.spec._replace(scheduler=SchedulerSpec(slots=4)))
        assert svc.scheduler.slots == 4
        assert _signature(svc.serve(reqs)) == base

    def test_frozen_registry_fields_raise(self, no_mesh):
        svc, _ = self._boot()
        for field, value in (("num_features", 128), ("k_max", 4),
                             ("class_bucket", 32)):
            with pytest.raises(ReconfigureError, match=field):
                svc.reconfigure(svc.spec._replace(
                    registry=svc.spec.registry._replace(**{field: value})))

    def test_noop_reconfigure(self, no_mesh):
        svc, _ = self._boot()
        report = svc.reconfigure(svc.spec)
        assert report.actions == () and report.downtime_s == 0.0


def run_sub(code: str, timeout=600) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["JAX_PLATFORMS"] = "cpu"
    # the child pins its own forced device count before importing jax
    env.pop("XLA_FLAGS", None)
    env.pop("REPRO_FORCE_MESH", None)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


class TestForcedMeshControlPlane:
    """The spec path owning a real (data, model) mesh end to end."""

    def test_live_reshard_sharded_bit_identity(self):
        out = run_sub("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
            import jax
            import numpy as np
            from repro import match
            from repro.match.config import EngineConfig
            from repro.serve.acam_service import (ClassifyRequest,
                                                  make_synthetic_tenant,
                                                  sample_tenant_queries)
            from repro.serve.control import HybridService
            from repro.serve.registry import TemplateBankRegistry
            from repro.serve.spec import (CascadeSpec, MeshSpec,
                                          RegistrySpec, SchedulerSpec,
                                          ServiceSpec)

            spec = ServiceSpec(
                registry=RegistrySpec(num_features=64, initial_classes=256),
                engine=EngineConfig(backend="kernel", margin=True),
                mesh=MeshSpec(bank_shards=1),   # install=True: spec owns it
                scheduler=SchedulerSpec(slots=64),
                cascade=CascadeSpec(tau=6.0, tau_units="count"))
            svc = HybridService.from_spec(spec)
            assert match.bank_shards_in_mesh() == 1  # (data=4, model=1)

            protos = {}
            for t in range(4):  # 40-class tenants: runs straddle row 128
                bank, head, p = make_synthetic_tenant(
                    1000 + 17 * t, num_classes=40, num_features=64)
                svc.register_tenant(f"t{t}", bank, head=head)
                protos[f"t{t}"] = p
            reqs = []
            for i, (tid, p) in enumerate(sorted(protos.items())):
                f, _ = sample_tenant_queries(7 + i, p, 32, noise=0.9)
                reqs += [ClassifyRequest(tid, f[j]) for j in range(32)]
            sig = lambda rs: [(r.tenant_id, r.pred, r.escalated,
                               round(r.margin, 6)) for r in rs]
            base = sig(svc.serve(reqs))
            assert any(s[2] for s in base) and any(not s[2] for s in base)

            registered = {"n": 0}
            orig = TemplateBankRegistry.register
            def counting(self, *a, **kw):
                registered["n"] += 1
                return orig(self, *a, **kw)
            TemplateBankRegistry.register = counting
            try:
                report = svc.reconfigure(spec._replace(
                    mesh=MeshSpec(bank_shards=2)))
            finally:
                TemplateBankRegistry.register = orig
            assert registered["n"] == 0, "reshard re-registered tenants"
            assert report.tenants_moved >= 1
            assert match.bank_shards_in_mesh() == 2  # (data=2, model=2)
            assert svc.registry.bank_shards == 2
            rps = svc.registry.rows_per_shard
            for tid in protos:
                e = svc.registry.get(tid)
                assert e.offset // rps == \
                    (e.offset + e.c_bucket - 1) // rps, (tid, e)

            # the tick shapes now derive a bank-sharded 2D plan (the real
            # sharded-dispatch check; dispatches == ticks is structural)
            plan, _ = match.plan_for(
                batch=64, num_classes=svc.registry.capacity_classes)
            assert plan.bank_shards == 2 and plan.dp_devices == 2, plan

            svc.reset_metrics()
            sharded = sig(svc.serve(reqs))
            assert sharded == base, "reshard changed served results"
            m = svc.metrics()
            assert m["classify_dispatches"] == m["ticks"]  # ONE per tick
            print("OK sharded", m["classify_dispatches"])

            svc.reconfigure(svc.spec._replace(mesh=MeshSpec(bank_shards=1)))
            assert match.bank_shards_in_mesh() == 1
            assert sig(svc.serve(reqs)) == base
            print("OK back-to-one")
            """, timeout=900)
        assert "OK sharded" in out and "OK back-to-one" in out

    def test_per_shard_device_noise_matches_emulated_tiling(self):
        out = run_sub("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
            import jax, jax.numpy as jnp
            import numpy as np
            from repro import match
            from repro.core import acam
            from repro.core.templates import TemplateBank
            from repro.distributed import context

            key = jax.random.PRNGKey(0)
            c, k, n, b = 64, 1, 32, 16
            tmpl = (jax.random.uniform(key, (c, k, n)) > 0.5
                    ).astype(jnp.float32)
            bank = TemplateBank(tmpl, jnp.zeros_like(tmpl),
                                jnp.ones_like(tmpl), jnp.ones((c, k), bool),
                                jnp.zeros((n,)))
            feats = jax.random.normal(jax.random.fold_in(key, 1), (b, n))

            eng = match.engine_for(
                backend="device",
                device=acam.ACAMConfig(sigma_program=0.2), seed=9,
                device_noise="per_shard")
            assert eng.backend(None).supports_bank_sharding
            # "global" noise still declines sharding at sigma > 0
            glob = match.engine_for(
                backend="device",
                device=acam.ACAMConfig(sigma_program=0.2), seed=9)
            assert not glob.backend(None).supports_bank_sharding

            # replicated emulation of the 2-array tiling (no mesh)
            pe, pce = eng.backend(None).classify_features_keyed(
                feats, bank, jax.random.PRNGKey(9), bank_shards=2)

            mesh = jax.make_mesh((2, 2), ("data", "model"))
            context.set_mesh_axes("data", "model", mesh)
            plan, _ = match.plan_for(batch=b, num_classes=c)
            assert plan.bank_shards == 2, plan
            ps, pcs = eng.classify_features(feats, bank)
            context.clear()
            np.testing.assert_array_equal(np.asarray(ps), np.asarray(pe))
            np.testing.assert_array_equal(np.asarray(pcs), np.asarray(pce))
            # distinct, documented semantics: != the one-array noise field
            pg, pcg = glob.backend(None).classify_features_keyed(
                feats, bank, jax.random.PRNGKey(9))
            assert not np.allclose(np.asarray(pcg), np.asarray(pce))
            print("OK per-shard")
            """, timeout=900)
        assert "OK per-shard" in out
