"""2D-sharded matching: the PartitionPlan bank-sharding contracts (PR 4).

Three layers of coverage:

  * shard-aligned registry (in-process): `TemplateBankRegistry(bank_shards=S)`
    keeps capacity divisible by S, never places a tenant's bucket run across
    a shard boundary (allocations skip to the next shard instead), and
    preserves alignment across capacity growth and evict/re-register churn;
  * chunked margins kernel (in-process): banks past `MAX_FUSED_ROWS` stay a
    single pallas_call and agree bit-for-bit with the resident fused-margins
    kernel and the jnp oracle;
  * forced 2x2 CPU mesh (subprocess, XLA_FLAGS before jax import): the
    bank-sharded engine and the FULL ACAMService tick are bit-identical to
    replicated execution — predictions, margins, escalation set — for
    B in {256, 1024}, for tenant windows adjacent to shard edges, bucket-
    padded rows, shard-straddling layouts the allocator must re-place, and
    evict/re-register across a shard, with exactly ONE sharded dispatch per
    scheduler tick; the XOR-butterfly tree reduce (`PartitionPlan.reduce`,
    REPRO_REDUCE_STRATEGY=tree) agrees bit-for-bit with the all-gather fold
    and with replicated execution.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import match
from repro.core.templates import TemplateBank
from repro.kernels import layout
from repro.serve import acam_service as svc_lib
from repro.serve.registry import TemplateBankRegistry

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
N_FEATURES = 64


def _tenant(seed, classes):
    return svc_lib.make_synthetic_tenant(seed, num_classes=classes,
                                         num_features=N_FEATURES)


def _no_straddle(reg):
    rps = reg.rows_per_shard
    for tid in list(reg._tenants):
        e = reg.get(tid)
        first, last = e.offset, e.offset + e.c_bucket - 1
        assert first // rps == last // rps, (tid, e, rps)


class TestShardAlignedRegistry:
    def test_capacity_rounds_up_to_shard_multiple(self):
        reg = TemplateBankRegistry(N_FEATURES, class_bucket=16,
                                   initial_classes=48, bank_shards=4)
        assert reg.capacity_classes % (4 * 16) == 0
        assert reg.rows_per_shard * 4 == reg.capacity_classes
        assert reg.stats()["bank_shards"] == 4

    def test_bad_bank_shards_raises(self):
        with pytest.raises(ValueError):
            TemplateBankRegistry(N_FEATURES, bank_shards=0)

    def test_allocations_never_straddle_a_shard(self):
        # 48-row tenants on a 256-row, 2-shard bank: the third tenant would
        # span rows [96, 144) across the row-128 boundary — the allocator
        # must skip it to offset 128 (rows 96..128 stay masked padding)
        reg = TemplateBankRegistry(N_FEATURES, class_bucket=16,
                                   initial_classes=256, bank_shards=2)
        offsets = []
        for t in range(3):
            bank, _, _ = _tenant(400 + t, 40)
            offsets.append(reg.register(f"t{t}", bank).offset)
        assert offsets == [0, 48, 128]
        _no_straddle(reg)
        # the skipped rows are not programmed
        sb = reg.device_bank()
        assert not np.asarray(sb.valid[96:128]).any()

    def test_growth_preserves_alignment(self):
        reg = TemplateBankRegistry(N_FEATURES, class_bucket=16,
                                   initial_classes=64, bank_shards=2)
        for t in range(6):  # 6 x 16-row buckets > 64 rows: forces growth
            bank, _, _ = _tenant(500 + t, 10)
            reg.register(f"t{t}", bank)
        assert reg.capacity_classes == 128
        assert reg.capacity_classes % (2 * 16) == 0
        _no_straddle(reg)

    def test_churn_keeps_alignment(self):
        reg = TemplateBankRegistry(N_FEATURES, class_bucket=16,
                                   initial_classes=128, bank_shards=2)
        for t in range(4):
            bank, _, _ = _tenant(600 + t, 24)
            reg.register(f"t{t}", bank)
        reg.evict("t1")
        big, _, _ = _tenant(660, 40)  # bigger than the freed 32-row range
        reg.register("big", big)
        small, _, _ = _tenant(661, 10)
        reg.register("re", small)
        _no_straddle(reg)

    def test_unsharded_default_unchanged(self):
        reg = TemplateBankRegistry(N_FEATURES)
        assert reg.bank_shards == 1
        assert reg.rows_per_shard == reg.capacity_classes


class TestChunkedMarginsKernel:
    def test_class_chunk_selection(self):
        assert layout.class_chunk(1152, 2, 2048) == 384
        assert layout.class_chunk(256, 2, 2048) == 256
        assert layout.class_chunk(4096, 1, 2048) == 2048
        # even one lane tile of K slices over budget: lane fallback
        assert layout.class_chunk(128, 32, 2048) == 128

    def test_stack_kcp_roundtrip(self):
        key = jax.random.PRNGKey(0)
        arr = jax.random.normal(key, (10, 2, 8))
        stacked = layout.stack_kcp(arr, 10)
        assert stacked.shape == (2, 128, 8)
        np.testing.assert_array_equal(np.asarray(stacked[1, :10]),
                                      np.asarray(arr[:, 1, :]))
        assert not np.asarray(stacked[:, 10:]).any()

    @pytest.mark.parametrize("c,k", [(1100, 2), (300, 8)])
    def test_big_bank_margins_single_dispatch_parity(self, c, k):
        # both shapes exceed MAX_FUSED_ROWS: Cp(1100)*2 = 2304,
        # Cp(300)*8 = 3072
        key = jax.random.PRNGKey(4)
        n, b = 96, 16
        tmpl = (jax.random.uniform(key, (c, k, n)) > 0.5).astype(jnp.float32)
        valid = jnp.ones((c, k), bool).at[2, k - 1].set(False)
        valid = valid.at[c - 1, :].set(False)
        bank = TemplateBank(tmpl, jnp.zeros_like(tmpl), jnp.ones_like(tmpl),
                            valid,
                            jax.random.normal(jax.random.fold_in(key, 1),
                                              (n,)) * 0.1)
        assert k * layout.padded_classes(c) > match.MAX_FUSED_ROWS
        feats = jax.random.normal(jax.random.fold_in(key, 2), (b, n))
        rng = np.random.RandomState(c)
        lo = jnp.asarray(rng.randint(0, c - 4, size=b), jnp.int32)
        hi = jnp.minimum(lo + rng.randint(1, 100, size=b), c).astype(jnp.int32)
        hi = hi.at[0].set(lo[0])  # empty window: pred 0, margin 0

        ker = match.engine_for(backend="kernel")
        ref = match.engine_for(backend="reference")
        p_k, pc_k, m_k = ker.classify_features_margin(feats, bank, lo, hi)
        p_r, pc_r, m_r = ref.classify_features_margin(feats, bank, lo, hi)
        np.testing.assert_array_equal(np.asarray(p_k), np.asarray(p_r))
        np.testing.assert_array_equal(np.asarray(pc_k), np.asarray(pc_r))
        np.testing.assert_array_equal(np.asarray(m_k), np.asarray(m_r))
        assert float(m_k[0]) == 0.0 and int(p_k[0]) == 0

    def test_matches_resident_fused_kernel_bit_for_bit(self):
        from repro.kernels.acam_match import ops as match_ops

        key = jax.random.PRNGKey(5)
        c, k, n, b = 1100, 2, 64, 8
        tmpl = (jax.random.uniform(key, (c, k, n)) > 0.5).astype(jnp.float32)
        valid = jnp.ones((c, k), bool)
        thr = jnp.zeros((n,))
        feats = jax.random.normal(jax.random.fold_in(key, 1), (b, n))
        lo = jnp.zeros((b,), jnp.int32)
        hi = jnp.full((b,), c, jnp.int32)
        p1, pc1, m1 = match_ops.classify_fused_margins(
            feats, thr, tmpl, valid, lo, hi)
        p2, pc2, m2 = match_ops.classify_fused_margins_chunked(
            feats, thr, tmpl, valid, lo, hi, max_rows=match.MAX_FUSED_ROWS)
        np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))
        np.testing.assert_array_equal(np.asarray(pc1), np.asarray(pc2))
        np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))


def run_sub(code: str, timeout=600) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["JAX_PLATFORMS"] = "cpu"
    # the child pins its own forced device count before importing jax
    env.pop("XLA_FLAGS", None)
    env.pop("REPRO_FORCE_MESH", None)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


class TestForced2x2Mesh:
    """Bank-sharded vs replicated bit-identity on a forced 2x2 CPU mesh."""

    def test_engine_bit_identical_2d_sharded(self):
        out = run_sub("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
            import jax, jax.numpy as jnp
            import numpy as np
            from repro import match
            from repro.core.templates import TemplateBank
            from repro.distributed import context

            key = jax.random.PRNGKey(0)
            c, k, n = 256, 2, 128
            tmpl = (jax.random.uniform(key, (c, k, n)) > 0.5
                    ).astype(jnp.float32)
            valid = jnp.ones((c, k), bool).at[0, 1].set(False)
            valid = valid.at[c - 1, 0].set(False)
            bank = TemplateBank(tmpl, jnp.zeros_like(tmpl),
                                jnp.ones_like(tmpl), valid, jnp.zeros((n,)))
            eng = match.engine_for(backend="kernel")

            for b in (256, 1024):
                feats = jax.random.normal(jax.random.fold_in(key, b), (b, n))
                rng = np.random.RandomState(b)
                # windows adjacent to AND straddling the row-128 shard edge
                lo = rng.randint(0, c - 8, size=b)
                lo[:4] = (120, 128, 100, 0)
                hi = np.minimum(lo + rng.randint(1, 64, size=b), c)
                hi[:4] = (128, 160, 156, c)
                lo, hi = jnp.asarray(lo, jnp.int32), jnp.asarray(hi, jnp.int32)

                context.clear()
                pred1, pc1 = eng.classify_features(feats, bank)
                p1, _, m1 = eng.classify_features_margin(feats, bank, lo, hi)

                mesh = jax.make_mesh((2, 2), ("data", "model"))
                context.set_mesh_axes("data", "model", mesh)
                plan, _ = match.plan_for(batch=b, num_classes=c)
                assert plan.bank_shards == 2 and plan.dp_devices == 2, plan
                assert plan.rows_per_shard == 128
                pred2, pc2 = eng.classify_features(feats, bank)
                p2, _, m2 = eng.classify_features_margin(feats, bank, lo, hi)
                context.clear()

                # the batch really ran split over the data axis
                assert len(pred2.sharding.device_set) >= 2
                assert np.array_equal(np.asarray(pred1), np.asarray(pred2))
                assert np.array_equal(np.asarray(pc1), np.asarray(pc2))
                assert np.array_equal(np.asarray(p1), np.asarray(p2))
                assert np.array_equal(np.asarray(m1), np.asarray(m2))
                print("OK", b)
            """)
        assert out.count("OK") == 2

    def test_service_bit_identical_one_dispatch_per_tick(self):
        out = run_sub("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
            import jax
            import numpy as np
            from repro import match
            from repro.distributed import context
            from repro.serve import acam_service as svc_lib

            # two shard-straddling tenant layouts: (a) 24-class tenants
            # packing shard 0 edge-to-edge (windows adjacent to row 128),
            # (b) 40-class tenants whose third placement would straddle the
            # boundary and must be re-placed to shard 1 (rows 96-128 become
            # masked padding)
            LAYOUTS = {"edge_packed": [24] * 7, "straddle_skip": [40] * 4}

            def build_and_serve(layout, slots, churn):
                svc = svc_lib.ACAMService(
                    64, config=svc_lib.ServiceConfig(slots=slots,
                                                     margin_tau=6.0))
                protos = {}
                for t, classes in enumerate(LAYOUTS[layout]):
                    bank, head, p = svc_lib.make_synthetic_tenant(
                        1000 + 17 * t, num_classes=classes, num_features=64)
                    svc.register_tenant(f"t{t}", bank, head=head)
                    protos[f"t{t}"] = p
                if churn:
                    # evict from shard 0, re-register landing across a shard
                    svc.evict_tenant("t1")
                    bank, head, p = svc_lib.make_synthetic_tenant(
                        2000, num_classes=40, num_features=64)
                    svc.register_tenant("tx", bank, head=head)
                    protos["tx"] = p
                    del protos["t1"]
                calls = {"n": 0}
                orig = match.MatchEngine.classify_serve
                def counting(self, *a, **kw):
                    calls["n"] += 1
                    return orig(self, *a, **kw)
                match.MatchEngine.classify_serve = counting
                try:
                    reqs = []
                    for i, (tid, p) in enumerate(sorted(protos.items())):
                        f, _ = svc_lib.sample_tenant_queries(
                            7 + i, p, 40, noise=0.9)
                        reqs += [svc_lib.ClassifyRequest(tid, f[j])
                                 for j in range(40)]
                    rs = svc.serve(reqs)
                finally:
                    match.MatchEngine.classify_serve = orig
                stats = svc.scheduler.stats
                assert stats.classify_dispatches == stats.ticks
                assert 1 <= calls["n"] <= stats.ticks  # one engine
                # dispatch per tick: traces <= ticks, replays otherwise
                return svc, [(r.tenant_id, r.pred, r.escalated,
                              round(r.margin, 6)) for r in rs]

            for layout in LAYOUTS:
                for slots, churn in ((64, False), (16, True)):
                    context.clear()
                    svc1, out1 = build_and_serve(layout, slots, churn)
                    assert svc1.registry.bank_shards == 1

                    mesh = jax.make_mesh((2, 2), ("data", "model"))
                    context.set_mesh_axes("data", "model", mesh)
                    svc2, out2 = build_and_serve(layout, slots, churn)
                    context.clear()
                    assert svc2.registry.bank_shards == 2
                    rps = svc2.registry.rows_per_shard
                    for tid in list(svc2.registry._tenants):
                        e = svc2.registry.get(tid)
                        assert e.offset // rps == \
                            (e.offset + e.c_bucket - 1) // rps, (tid, e)
                    assert out1 == out2, layout
                    assert any(esc for _, _, esc, _ in out1)
                    assert any(not esc for _, _, esc, _ in out1)
                    print("OK", layout, slots, churn)
            """, timeout=900)
        assert out.count("OK") == 4

    def test_tree_reduce_bit_identical_to_allgather(self):
        """The XOR-butterfly cross-shard reduce (REPRO_REDUCE_STRATEGY=tree)
        yields the same bits as the all-gather fold AND as replicated
        execution — winner, margins, per-class scores, escalation set."""
        out = run_sub("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
            import jax, jax.numpy as jnp
            import numpy as np
            from repro import match
            from repro.core.templates import TemplateBank
            from repro.distributed import context

            assert match.reduce_strategy(8) == "tree"       # default past 8
            assert match.reduce_strategy(2) == "allgather"  # small axis
            assert match.reduce_strategy(6) == "allgather"  # not a pow2
            os.environ["REPRO_REDUCE_STRATEGY"] = "tree"
            assert match.reduce_strategy(2) == "tree"       # env override
            assert match.reduce_strategy(6) == "allgather"  # pow2 required

            key = jax.random.PRNGKey(7)
            c, k, n, b, T = 256, 2, 128, 256, 8
            tmpl = (jax.random.uniform(key, (c, k, n)) > 0.5
                    ).astype(jnp.float32)
            valid = jnp.ones((c, k), bool).at[0, 1].set(False)
            bank = TemplateBank(tmpl, jnp.zeros_like(tmpl),
                                jnp.ones_like(tmpl), valid, jnp.zeros((n,)))
            eng = match.engine_for(backend="kernel")
            feats = jax.random.normal(jax.random.fold_in(key, 1), (b, n))
            thr_table = jax.random.normal(jax.random.fold_in(key, 2),
                                          (T, n)) * 0.1
            rng = np.random.RandomState(3)
            slot = jnp.asarray(rng.randint(0, T, b), jnp.int32)
            lo = rng.randint(0, c - 8, size=b)
            lo[:2] = (120, 100)  # windows straddling the row-128 shard edge
            hi = np.minimum(lo + rng.randint(1, 64, size=b), c)
            hi[:2] = (160, 156)
            lo, hi = jnp.asarray(lo, jnp.int32), jnp.asarray(hi, jnp.int32)
            tau = jnp.asarray(rng.uniform(0, 12, b), jnp.float32)

            context.clear()
            rep = eng.classify_serve(feats, thr_table, slot, bank, lo, hi,
                                     tau)
            pm_rep = eng.classify_features_margin(feats, bank, lo, hi)

            results = {}
            for strat in ("allgather", "tree"):
                os.environ["REPRO_REDUCE_STRATEGY"] = strat
                mesh = jax.make_mesh((2, 2), ("data", "model"))
                context.set_mesh_axes("data", "model", mesh)
                plan, _ = match.plan_for(batch=b, num_classes=c)
                assert plan.bank_shards == 2 and plan.reduce == strat, plan
                results[strat] = (
                    eng.classify_serve(feats, thr_table, slot, bank, lo, hi,
                                       tau),
                    eng.classify_features_margin(feats, bank, lo, hi))
                context.clear()

            for strat, (serve, pm) in results.items():
                for a, b_ in zip(rep, serve):
                    assert np.array_equal(np.asarray(a), np.asarray(b_)), \
                        (strat, "serve")
                for a, b_ in zip(pm_rep, pm):
                    assert np.array_equal(np.asarray(a), np.asarray(b_)), \
                        (strat, "margin")
            esc = np.asarray(rep[3])
            assert esc.any() and not esc.all()
            print("OK tree")
            """)
        assert "OK tree" in out

    def test_repro_force_mesh_env_path(self):
        """The CI entry: REPRO_FORCE_MESH=2x2 via forcemesh two-phase."""
        out = run_sub("""
            import os
            os.environ["REPRO_FORCE_MESH"] = "2x2"
            from repro.distributed import forcemesh
            assert forcemesh.apply_xla_flags()
            import jax
            mesh = forcemesh.install()
            assert mesh is not None and len(jax.devices()) == 4
            from repro import match
            assert match.bank_shards_in_mesh() == 2
            plan, _ = match.plan_for(batch=64, num_classes=128)
            assert plan.bank_shards == 2 and plan.dp_devices == 2
            print("OK env")
            """)
        assert "OK env" in out
