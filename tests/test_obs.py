"""Flight-recorder tests (PR 7): `repro.obs` and its serving-tier wiring.

Coverage layers:

  * registry primitives: labeled counters/gauges, the dual-view histogram
    (exact-from-buckets quantiles, cumulative-clears vs window-survives),
    the label-cardinality guard, kind-conflict detection;
  * span ledger: open-once/close-once conservation as a structural
    property, deterministic request-id sampling;
  * energy ledger: fleet and per-tenant totals BIT-EXACT (`==`, not
    approx) with the left-fold sum over per-response attributions;
  * exporters: JSONL schema round-trip, torn-final-line tolerance,
    Prometheus exposition rendering + duplicate/cardinality validation;
  * service integration over the bursty trace harness: span counts ==
    request counts across every disposition (ok/escalated/shed/expired),
    `reset_metrics()` exact clear/survive semantics, tick events
    reconcile with the registry, and telemetry on-vs-off serves
    bit-identical results with <5% latency overhead.
"""
import json
import os
import sys
import time

import numpy as np
import pytest

from repro.match.config import EngineConfig
from repro.obs import (DEFAULT_LATENCY_BUCKETS_MS, EnergyLedger,
                       FlightRecorder, JsonlEventLog, MetricsRegistry,
                       read_events, validate_event,
                       validate_prometheus_text)
from repro.obs.registry import MAX_LABEL_SETS, Histogram
from repro.obs.spans import SpanRecorder, sampled
from repro.serve import acam_service as svc_lib
from repro.serve import spec as spec_lib
from repro.serve.acam_service import AdmissionError, ClassifyRequest
from repro.serve.control import HybridService

BENCH = os.path.join(os.path.dirname(__file__), "..", "benchmarks")

N_FEATURES = 64
N_CLASSES = 6
N_TENANTS = 6
SLOTS = 16


def _traces():
    if BENCH not in sys.path:
        sys.path.insert(0, BENCH)
    import traces

    return traces


def _spec(slots=SLOTS, *, deadline_ms=None, shed_queue=None,
          obs=None) -> spec_lib.ServiceSpec:
    return spec_lib.ServiceSpec(
        registry=spec_lib.RegistrySpec(num_features=N_FEATURES),
        engine=EngineConfig(margin=True),
        mesh=spec_lib.MeshSpec(install=False),
        scheduler=spec_lib.SchedulerSpec(slots=slots),
        cascade=spec_lib.CascadeSpec(tau=8.0, tau_units="count",
                                     deadline_ms=deadline_ms,
                                     shed_queue=shed_queue),
        obs=obs if obs is not None else spec_lib.ObsSpec(),
    ).validate()


def _boot(spec):
    svc = HybridService.from_spec(spec)
    protos = {}
    for t in range(N_TENANTS):
        bank, head, p = svc_lib.make_synthetic_tenant(
            200 + t, num_classes=N_CLASSES, num_features=N_FEATURES)
        tid = f"tenant-{t}"
        svc.register_tenant(tid, bank, head=head)
        protos[tid] = p
    return svc, protos


def _mixed_requests(protos, per_tenant=12, *, noise=0.9, seed=3):
    rng = np.random.RandomState(seed)
    reqs = []
    for ti, (tid, p) in enumerate(protos.items()):
        feats, _ = svc_lib.sample_tenant_queries(
            seed + 31 * ti, p, per_tenant, noise=noise)
        reqs.extend(ClassifyRequest(tid, feats[i])
                    for i in range(per_tenant))
    return [reqs[i] for i in rng.permutation(len(reqs))]


# ---------------------------------------------------------------------------
# Registry primitives
# ---------------------------------------------------------------------------

class TestRegistry:
    def test_counter_labels_and_reset(self):
        reg = MetricsRegistry()
        c = reg.counter("x_total")
        c.inc()
        c.inc(2, tenant="a")
        c.inc(3, tenant="b")
        assert c.value() == 1 and c.value(tenant="a") == 2
        assert c.total() == 6
        reg.reset()
        assert c.total() == 0
        # label sets survive a reset (only the values clear)
        assert [(labels, v) for labels, v in c.items()] == \
            [({}, 0.0), ({"tenant": "a"}, 0.0), ({"tenant": "b"}, 0.0)]

    def test_gauge_reset_semantics(self):
        reg = MetricsRegistry()
        g = reg.gauge("depth")
        per_run = reg.gauge("fill_min", clear_on_reset=True)
        g.set(7)
        per_run.set_min(5)
        per_run.set_min(3)
        assert per_run.value() == 3
        reg.reset()
        assert g.value() == 7, "plain gauges must survive reset"
        assert per_run.value() == 0, "clear_on_reset gauges must not"

    def test_registered_twice_returns_same_family(self):
        reg = MetricsRegistry()
        assert reg.counter("x") is reg.counter("x")
        with pytest.raises(ValueError, match="already registered"):
            reg.gauge("x")

    def test_label_cardinality_guard(self):
        c = MetricsRegistry().counter("leak_total")
        for i in range(MAX_LABEL_SETS):
            c.inc(request=i)
        with pytest.raises(ValueError, match="cardinality"):
            c.inc(request=MAX_LABEL_SETS)

    def test_histogram_exact_quantiles(self):
        h = Histogram("lat", buckets=(1.0, 2.0, 4.0))
        for v in (0.5, 1.5, 3.0, 8.0):
            h.observe(v)
        # one observation per bucket (incl. +Inf): the q-rank observation
        # lands on a bucket upper bound exactly, no estimation slack
        assert h.quantile(0.25) == 1.0
        assert h.quantile(0.5) == 2.0
        assert h.quantile(0.75) == 4.0
        assert h.quantile(1.0) == 4.0  # +Inf bucket clamps to last bound
        assert h.quantile(0.5, window=False) == h.quantile(0.5)

    def test_histogram_dual_view_reset(self):
        h = Histogram("lat", buckets=(1.0, 2.0, 4.0), window=8)
        for v in (0.5, 1.5, 3.0):
            h.observe(v)
        p50 = h.quantile(0.5)
        h.clear()
        assert h.count == 0 and sum(h.counts) == 0, "cumulative cleared"
        assert h.window_count == 3 and h.quantile(0.5) == p50, \
            "rolling window must survive (overload signal)"
        assert h.quantile(0.5, window=False) == 0.0

    def test_histogram_window_bounded(self):
        h = Histogram("lat", buckets=(1.0, 10.0), window=4)
        for _ in range(16):
            h.observe(0.5)
        for _ in range(4):
            h.observe(5.0)  # the window now holds ONLY the slow tail
        assert h.window_count == 4
        assert h.quantile(0.5) > 1.0
        assert h.count == 20, "cumulative view keeps everything"

    def test_histogram_rejects_bad_buckets(self):
        with pytest.raises(ValueError, match="increasing"):
            Histogram("h", buckets=(2.0, 1.0))
        with pytest.raises(ValueError, match="window"):
            Histogram("h", buckets=(1.0,), window=0)


# ---------------------------------------------------------------------------
# Spans
# ---------------------------------------------------------------------------

class TestSpans:
    def test_open_close_conservation(self):
        rec = SpanRecorder()
        rec.start(1, "t0", 10.0)
        rec.start(2, "t0", 10.0)
        rec.dequeue(1, tick_id=0, t_dequeue=10.5)
        span = rec.finish(1, "ok", t_done=11.0)
        assert span.tick_id == 0
        assert span.queue_ms == pytest.approx(500.0)
        assert span.service_ms == pytest.approx(500.0)
        c = rec.conservation()
        assert c["started"] == 2 and c["finished"] == 1
        assert c["in_flight"] == 1
        assert c["by_disposition"] == {"ok": 1}
        # a finish pops: the same id cannot close a span twice
        assert rec.finish(1, "ok") is None

    def test_unknown_disposition_rejected(self):
        with pytest.raises(ValueError, match="disposition"):
            SpanRecorder().finish(1, "vanished")

    def test_sampling_deterministic(self):
        verdicts = [sampled(i, 0.5) for i in range(512)]
        assert verdicts == [sampled(i, 0.5) for i in range(512)]
        assert 0.3 < np.mean(verdicts) < 0.7
        assert all(sampled(i, 1.0) for i in range(64))
        assert not any(sampled(i, 0.0) for i in range(64))

    def test_sampled_out_still_counts(self):
        rec = SpanRecorder(sample_rate=0.0)
        assert rec.start(7, "t0") is None
        rec.finish(7, "ok")
        c = rec.conservation()
        assert c["started"] == c["finished"] == 1 and c["in_flight"] == 0


# ---------------------------------------------------------------------------
# Energy ledger
# ---------------------------------------------------------------------------

class TestEnergyLedger:
    def test_bit_exact_with_left_fold(self):
        rng = np.random.RandomState(0)
        ledger = EnergyLedger()
        energies = []
        for i in range(500):
            b = float(rng.uniform(1e-9, 3e-9))
            f = float(rng.uniform(0, 1e-7)) if i % 3 == 0 else 0.0
            ledger.add(f"t{i % 4}", b, f, escalated=bool(f))
            energies.append(b + f)
        total = 0.0
        for e in energies:
            total += e
        assert ledger.fleet_j() == total, "must be ==, not approx"
        assert ledger.backend_j() + ledger.frontend_j() == \
            pytest.approx(total)

    def test_fleet_summary(self):
        ledger = EnergyLedger()
        ledger.add("a", 1e-9, 0.0)
        ledger.add("a", 1e-9, 9e-8, escalated=True)
        ledger.add("b", 1e-9, 0.0, shed=True)
        f = ledger.fleet()
        assert f["requests"] == 3 and f["escalated"] == 1 and f["shed"] == 1
        assert f["total_nj"] == pytest.approx(93.0)
        assert f["backend_share"] == pytest.approx(3e-9 / 9.3e-8)
        per = ledger.per_tenant()
        assert set(per) == {"a", "b"}
        assert per["b"]["frontend_nj"] == 0.0
        ledger.clear()
        assert ledger.fleet_j() == 0.0 and ledger.per_tenant() == {}


# ---------------------------------------------------------------------------
# Exporters
# ---------------------------------------------------------------------------

class TestExporters:
    def test_jsonl_roundtrip(self, tmp_path):
        log = JsonlEventLog(tmp_path / "events.jsonl")
        log.emit("reshard", bank_shards_from=1, bank_shards_to=2)
        log.emit("device_loss", lost=[1], survivors=3)
        log.close()
        events = read_events(tmp_path / "events.jsonl")
        assert [e["kind"] for e in events] == ["reshard", "device_loss"]
        assert [e["seq"] for e in events] == [0, 1]

    def test_emit_validates_before_writing(self, tmp_path):
        log = JsonlEventLog(tmp_path / "events.jsonl")
        with pytest.raises(ValueError, match="missing fields"):
            log.emit("reshard", bank_shards_from=1)  # no ..._to
        with pytest.raises(ValueError, match="unknown event kind"):
            log.emit("made_up")
        log.close()
        assert read_events(tmp_path / "events.jsonl") == []

    def test_torn_final_line_tolerated(self, tmp_path):
        p = tmp_path / "events.jsonl"
        log = JsonlEventLog(p)
        log.emit("device_heal", restored=4)
        log.close()
        with open(p, "a") as fh:
            fh.write('{"kind": "tick", "ts"')  # SIGKILL mid-write
        events = read_events(p)
        assert len(events) == 1
        # ...but corruption BEFORE the final line fails loudly
        with open(p, "a") as fh:
            fh.write('\n{"kind": "device_heal", "restored": 1, '
                     '"ts": 0, "seq": 9}\n')
        with pytest.raises(ValueError, match="non-final"):
            read_events(p)

    def test_disabled_log_is_noop(self):
        log = JsonlEventLog(None)
        assert not log.enabled
        log.emit("snapshot", step=1, path="x")  # must not raise

    def test_prometheus_validator_catches_duplicates(self):
        with pytest.raises(ValueError, match="duplicate"):
            validate_prometheus_text("a_total 1\na_total 2\n")
        ok = validate_prometheus_text(
            'a_total{t="x"} 1\na_total{t="y"} 2\n')
        assert ok["series"] == 2


# ---------------------------------------------------------------------------
# FlightRecorder + service integration
# ---------------------------------------------------------------------------

class TestFlightRecorder:
    def test_default_construction(self):
        rec = FlightRecorder()
        assert rec.latency.buckets == DEFAULT_LATENCY_BUCKETS_MS
        assert not rec.events.enabled
        validate_prometheus_text(rec.render_prometheus())

    def test_obs_spec_validation(self):
        with pytest.raises(ValueError, match="increasing"):
            _spec(obs=spec_lib.ObsSpec(latency_buckets_ms=(2.0, 1.0)))
        with pytest.raises(ValueError, match="span_sample"):
            _spec(obs=spec_lib.ObsSpec(span_sample=1.5))
        with pytest.raises(ValueError, match="latency_window"):
            _spec(obs=spec_lib.ObsSpec(latency_window=0))

    def test_obs_spec_json_roundtrip(self):
        spec = _spec(obs=spec_lib.ObsSpec(
            latency_buckets_ms=(1.0, 5.0, 25.0), latency_window=64,
            span_sample=0.25))
        again = spec_lib.ServiceSpec.from_dict(json.loads(spec.to_json()))
        assert again == spec
        # snapshots written before the flight recorder existed still load
        legacy = spec.to_dict()
        del legacy["obs"]
        assert spec_lib.ServiceSpec.from_dict(legacy).obs == \
            spec_lib.ObsSpec()

    def test_straggler_sink_feeds_health(self):
        svc, _ = _boot(_spec())
        assert svc.scheduler.monitor.sink is not None
        svc.obs.record_straggler({"deadline_s": 1.5}, {0: 2, 3: 1})
        h = svc.health()
        assert h["straggler_strikes"] == {0: 2, 3: 1}
        assert svc.obs.straggler_deadline.value() == 1.5


class TestServiceTelemetry:
    @pytest.fixture(scope="class")
    def served(self):
        svc, protos = _boot(_spec())
        reqs = _mixed_requests(protos)
        responses = svc.serve(reqs)
        return svc, reqs, responses

    def test_energy_ledger_bit_exact(self, served):
        svc, _, responses = served
        total = 0.0
        for r in responses:
            total += r.energy_j
        assert svc.obs.ledger.fleet_j() == total, \
            "fleet ledger must equal the response left-fold EXACTLY"
        for tid in {r.tenant_id for r in responses}:
            per = 0.0
            for r in responses:
                if r.tenant_id == tid:
                    per += r.energy_j
            assert svc.obs.ledger.tenant_j(tid) == per, tid
        fleet = svc.obs.ledger.fleet()
        assert fleet["requests"] == len(responses)
        # the paper's asymmetry shows through: escalations dominate joules
        assert fleet["backend_share"] < 0.5

    def test_span_counts_equal_request_counts(self, served):
        svc, reqs, responses = served
        c = svc.obs.spans.conservation()
        m = svc.metrics()
        assert c["started"] == m["submitted"] == len(reqs)
        assert c["finished"] == m["completed"] == len(responses)
        assert c["in_flight"] == 0
        assert c["started"] == c["finished"] + c["in_flight"]
        assert c["by_disposition"].get("escalated", 0) == m["escalated"] > 0
        assert sum(c["by_disposition"].values()) == c["finished"]

    def test_finished_spans_carry_tick_attribution(self, served):
        svc, _, _ = served
        spans = list(svc.obs.spans.finished)
        assert spans
        for s in spans:
            assert s.tick_id >= 0
            assert s.disposition in ("ok", "escalated")
            assert s.total_ms >= s.service_ms >= 0.0
            assert s.dispatch_ms > 0.0

    def test_metrics_and_shed_check_read_same_quantile(self, served):
        svc, _, _ = served
        assert svc.metrics()["latency_p99_ms"] == \
            round(svc.obs.latency_quantile_ms(0.99), 3)

    def test_prometheus_export_of_live_service(self, served):
        svc, _, _ = served
        stats = validate_prometheus_text(svc.obs.render_prometheus())
        assert stats["families"] >= 20
        text = svc.obs.render_prometheus()
        assert "acam_request_latency_ms_bucket" in text
        assert 'acam_energy_joules_total{stage="backend"' in text

    def test_reset_metrics_exact_semantics(self, served):
        # runs LAST against the shared service: it mutates counters
        svc, _, _ = served
        svc.obs.queue_depth.set(7)  # pretend depth; must survive
        before = svc.obs.spans.conservation()
        tick_seq = svc.obs.tick_seq
        assert svc.metrics()["completed"] > 0
        p50_window = svc.obs.latency_quantile_ms(0.5)
        assert p50_window > 0
        svc.reset_metrics()
        m = svc.metrics()
        # CLEARED: counters, cumulative histogram, ledger, fill aggregates
        for key in ("submitted", "completed", "escalated", "ticks",
                    "classify_dispatches", "energy_total_j", "min_fill",
                    "max_fill", "tick_time_s"):
            assert not m[key], (key, m[key])
        assert svc.obs.latency.count == 0
        assert svc.scheduler.stats.ticks == 0  # legacy mirror follows
        # SURVIVING: gauges, rolling window, span totals, tick sequence
        assert svc.obs.queue_depth.value() == 7
        assert svc.obs.latency_quantile_ms(0.5) == p50_window, \
            "reset must never blind the shed_p99_ms overload signal"
        assert m["latency_p50_ms"] == round(p50_window, 3)
        assert svc.obs.spans.conservation() == before
        assert svc.obs.tick_seq == tick_seq


class TestBurstyTraceTelemetry:
    """Span/energy/event accounting under the bursty Zipf trace with the
    overload policy armed — every disposition in one run."""

    @pytest.fixture(scope="class")
    def replayed(self, tmp_path_factory):
        tr = _traces()
        td = tmp_path_factory.mktemp("telemetry")
        # query_noise high enough that below-margin requests show up in
        # burst AND calm phases: all of ok/escalated/shed in one replay
        cfg = tr.TraceConfig(seed=1, tenants=N_TENANTS, classes=N_CLASSES,
                             num_features=N_FEATURES, requests=192,
                             burst=64, calm=6, phase_ticks=2,
                             query_noise=1.2)
        spec = _spec(shed_queue=2 * SLOTS,
                     obs=spec_lib.ObsSpec(telemetry_dir=str(td)))
        svc = HybridService.from_spec(spec)
        pool = tr.TenantPool(cfg)
        pool.register_all(svc)
        svc, stats = tr.replay(svc, tr.make_trace(cfg), pool)
        return svc, stats, td

    def test_conservation_across_dispositions(self, replayed):
        svc, stats, _ = replayed
        c = svc.obs.spans.conservation()
        m = svc.metrics()
        assert c["started"] == m["submitted"] == stats["submitted"]
        assert c["finished"] == m["completed"] == stats["completed"]
        assert c["in_flight"] == svc.scheduler.qsize == 0
        assert c["by_disposition"].get("shed", 0) == m["shed"] > 0
        assert c["by_disposition"].get("escalated", 0) == m["escalated"] > 0
        assert c["by_disposition"].get("ok", 0) > 0

    def test_tick_events_reconcile_with_registry(self, replayed):
        svc, _, td = replayed
        events = read_events(td / "events.jsonl")  # validates every line
        ticks = [e for e in events if e["kind"] == "tick"]
        m = svc.metrics()
        assert sum(e["served"] + e["expired"] for e in ticks) \
            == m["completed"]
        assert sum(e["shed"] for e in ticks) == m["shed"]
        assert sum(1 for e in ticks if e["shed_mode"] and e["fill"]) \
            == m["load_shed_ticks"]
        total_j = sum(e["energy_j"] for e in ticks)
        assert total_j == pytest.approx(m["energy_total_j"], rel=1e-9)
        assert ticks[-1]["queue_depth"] == 0
        # dispatched ticks carry their tick id; the ids are unique
        ids = [e["tick_id"] for e in ticks if e["tick_id"] >= 0]
        assert len(ids) == len(set(ids)) == int(m["ticks"])

    def test_shed_flips_logged(self, replayed):
        svc, _, td = replayed
        events = read_events(td / "events.jsonl")
        on = sum(1 for e in events if e["kind"] == "shed_on")
        off = sum(1 for e in events if e["kind"] == "shed_off")
        assert on > 0, "burst phases must trip the overload policy"
        assert on - off in (0, 1)  # may end the trace still shedding


class TestDeadlineTelemetry:
    def test_expired_requests_close_spans(self):
        svc, protos = _boot(_spec(deadline_ms=1.0))
        reqs = _mixed_requests(protos, per_tenant=4)
        for r in reqs:
            svc.submit(r)
        time.sleep(0.01)  # everything queued is now past the 1ms deadline
        responses = svc.drain()
        assert all(r.error is not None and "deadline" in r.error
                   for r in responses)
        c = svc.obs.spans.conservation()
        assert c["by_disposition"] == {"expired": len(reqs)}
        assert c["in_flight"] == 0
        m = svc.metrics()
        assert m["expired"] == m["failed"] == len(reqs)
        # expired latencies measure the deadline, not service: kept OUT of
        # the latency histogram
        assert svc.obs.latency.count == 0

    def test_rejections_counted_not_started(self):
        svc, protos = _boot(_spec())
        with pytest.raises(AdmissionError):
            svc.submit(ClassifyRequest("nobody", np.zeros(N_FEATURES)))
        c = svc.obs.spans.conservation()
        assert c["started"] == 0
        assert svc.metrics()["rejected"] == 1


class TestTelemetryOverhead:
    def test_bit_identical_and_under_five_pct(self):
        """Telemetry observes, never steers: the full recorder (all spans
        + JSONL sink) must serve bit-identical preds/margins/escalations
        and cost <5% per-request latency vs spans-off/no-sink. Passes are
        INTERLEAVED (base, telemetry, base, telemetry, ...) and best-of-5
        so clock drift across the run — CPU frequency, GC pressure from
        earlier suite tests — hits both arms equally instead of reading
        as overhead."""
        import gc
        import tempfile

        def build(obs):
            # measured at the serving default (64 slots): the per-tick
            # JSONL write amortizes over a full micro-batch, which is the
            # regime the 5% budget is set for
            svc, protos = _boot(_spec(slots=64, obs=obs))
            reqs = _mixed_requests(protos, per_tenant=64)
            svc.serve(reqs)  # compiles every bucketed batch shape
            return svc, reqs

        def measure(svc, reqs):
            svc.reset_metrics()
            sig = [(r.tenant_id, r.pred, r.escalated, float(r.margin))
                   for r in svc.serve(reqs)]
            # the busy clock covers the whole step() — dispatch AND the
            # per-response telemetry bookkeeping under measurement
            return svc.obs.busy_seconds.value(), sig

        base_svc, base_reqs = build(spec_lib.ObsSpec(span_sample=0.0))
        with tempfile.TemporaryDirectory() as td:
            tel_svc, tel_reqs = build(
                spec_lib.ObsSpec(telemetry_dir=td, span_sample=1.0))
            base_sig = tel_sig = None
            best = None
            # true overhead is ~2% (the BENCH row tracks it), so scheduler
            # noise can eat the 5% headroom in any single attempt; a real
            # regression fails ALL attempts, noise doesn't
            for _ in range(3):
                gc.collect()  # earlier tests' garbage stays out of the timing
                base_ts, tel_ts = [], []
                for _ in range(5):
                    base_t, base_sig = measure(base_svc, base_reqs)
                    tel_t, tel_sig = measure(tel_svc, tel_reqs)
                    base_ts.append(base_t)
                    tel_ts.append(tel_t)
                overhead = min(tel_ts) / min(base_ts)
                best = overhead if best is None else min(best, overhead)
                if best < 1.05:
                    break
        assert tel_sig == base_sig, \
            "telemetry flipped a served result (must be pure observation)"
        assert best < 1.05, \
            f"telemetry overhead {100 * (best - 1):.1f}% >= 5% " \
            "(best of 3 interleaved attempts)"
