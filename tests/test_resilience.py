"""Fleet resilience (PR 6): durable service state, elastic failover,
overload policy, and the trace-driven chaos harness.

Coverage layers:

  * `Checkpointer` fault paths: an async-writer failure surfaces on the
    NEXT `save()`/`wait()` instead of being swallowed; a SIGKILL mid-save
    (real subprocess) never publishes a half-written step —
    `latest_step()` only returns complete dirs and the survivor restores
    bit-for-bit;
  * `StragglerMonitor.observe` single-stream policy + the scheduler's tick
    heartbeats feeding it;
  * overload policy: per-request deadlines expire queued work, load-shed
    mode answers from the ACAM stage alone (``shed=True`` where the margin
    asked for escalation), and the spec validates the thresholds eagerly;
  * snapshot/restore in-process: bit-identical serving with ZERO tenant
    re-registrations, restore onto a shrunk shard count, step sequencing;
  * forced 2x2 CPU mesh (subprocesses): a service killed after snapshot
    restores bit-identically in a FRESH process (same mesh and 2 -> 1
    shrunk mesh), and live device loss degrades onto the survivors with
    identical served results;
  * the trace harness: deterministic generation, Zipf skew, churn, and a
    replay with a mid-stream kill that recovers and finishes the trace.
"""
import json
import os
import subprocess
import sys
import textwrap
import time

import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.distributed import context
from repro.ft.elastic import StragglerMonitor
from repro.match.config import EngineConfig
from repro.serve.acam_service import (ClassifyRequest, make_synthetic_tenant,
                                      sample_tenant_queries)
from repro.serve.control import HybridService
from repro.serve.registry import TemplateBankRegistry
from repro.serve.snapshot import SnapshotError
from repro.serve.spec import (CascadeSpec, MeshSpec, RegistrySpec,
                              SchedulerSpec, ServiceSpec)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
BENCH = os.path.join(os.path.dirname(__file__), "..", "benchmarks")
N = 64


def _traces():
    if BENCH not in sys.path:
        sys.path.insert(0, BENCH)
    import traces

    return traces


def _spec(backend="reference", *, bank_shards=1, slots=16, tau=6.0,
          install=False, **cascade_kw):
    return ServiceSpec(
        registry=RegistrySpec(num_features=N, initial_classes=256),
        engine=EngineConfig(backend=backend, margin=True),
        mesh=MeshSpec(bank_shards=bank_shards, install=install),
        scheduler=SchedulerSpec(slots=slots),
        cascade=CascadeSpec(tau=tau, tau_units="count", **cascade_kw),
    )


def _populate(svc, classes=(40, 40, 40, 40)):
    protos = {}
    for t, c in enumerate(classes):
        bank, head, p = make_synthetic_tenant(1000 + 17 * t, num_classes=c,
                                              num_features=N)
        svc.register_tenant(f"t{t}", bank, head=head)
        protos[f"t{t}"] = p
    return protos


def _requests(protos, per_tenant=30, noise=0.9):
    reqs = []
    for i, (tid, p) in enumerate(sorted(protos.items())):
        f, _ = sample_tenant_queries(7 + i, p, per_tenant, noise=noise)
        reqs += [ClassifyRequest(tid, f[j]) for j in range(per_tenant)]
    return reqs


def _signature(responses):
    return [(r.tenant_id, r.pred, r.escalated, round(r.margin, 6))
            for r in responses]


@pytest.fixture
def no_mesh():
    saved_axes, saved_mesh = context.get(), context.get_mesh()
    context.clear()
    try:
        yield
    finally:
        context.clear()
        if saved_axes is not None:
            context.set_mesh_axes(saved_axes.dp, saved_axes.model,
                                  saved_mesh)


# ---------------------------------------------------------------------------
# Checkpointer fault paths
# ---------------------------------------------------------------------------

class TestCheckpointerAsyncErrors:
    """S1: a failed async write must surface, not vanish in the worker."""

    def _failing(self, ck, monkeypatch):
        def boom(step, flat, treedef):
            raise OSError("disk gone")

        monkeypatch.setattr(ck, "_write", boom)

    def _wait_for_error(self, ck, timeout=10.0):
        t0 = time.monotonic()
        while ck._error is None and time.monotonic() - t0 < timeout:
            time.sleep(0.01)
        assert ck._error is not None, "worker never recorded the failure"

    def test_error_surfaces_on_next_save(self, tmp_path, monkeypatch):
        ck = Checkpointer(tmp_path)
        self._failing(ck, monkeypatch)
        ck.save(0, {"a": np.arange(4)}, blocking=False)
        self._wait_for_error(ck)
        monkeypatch.undo()  # healthy again: only the REPORT must fire
        with pytest.raises(OSError, match="disk gone"):
            ck.save(1, {"a": np.arange(4)}, blocking=False)
        # the error was consumed; checkpointing recovers
        ck.save(2, {"a": np.arange(4)}, blocking=True)
        ck.wait()
        assert ck.latest_step() == 2

    def test_error_surfaces_on_wait(self, tmp_path, monkeypatch):
        ck = Checkpointer(tmp_path)
        self._failing(ck, monkeypatch)
        ck.save(0, {"a": np.arange(4)}, blocking=False)
        self._wait_for_error(ck)
        with pytest.raises(OSError, match="disk gone"):
            ck.wait()


class TestCrashConsistency:
    """S2: SIGKILL mid-save never publishes a torn step."""

    def test_sigkill_mid_save_keeps_only_complete_steps(self, tmp_path):
        child = textwrap.dedent(f"""
            import numpy as np
            from repro.checkpoint.checkpointer import Checkpointer
            ck = Checkpointer({str(tmp_path)!r}, keep=10_000)
            for s in range(10_000):
                tree = {{"bank": np.full((512, 512), s, np.float32),
                         "meta": {{"step": np.arange(s + 1)}}}}
                ck.save(s, tree)
                print("STEP", s, flush=True)
        """)
        env = dict(os.environ)
        env["PYTHONPATH"] = SRC
        proc = subprocess.Popen([sys.executable, "-c", child],
                                stdout=subprocess.PIPE, text=True, env=env)
        try:
            seen = -1
            for line in proc.stdout:
                if line.startswith("STEP"):
                    seen = int(line.split()[1])
                if seen >= 2:
                    break
            assert seen >= 2, "child never completed a save"
        finally:
            proc.kill()  # SIGKILL: no atexit, no cleanup
            proc.wait()

        ck = Checkpointer(tmp_path, keep=10_000)
        latest = ck.latest_step()
        assert latest is not None and latest >= 2
        # every published dir is complete (manifest present) and restores
        # to exactly what the child deterministically wrote for that step
        for p in sorted(tmp_path.glob("step_*")):
            if p.name.endswith(".tmp"):
                continue  # torn write, never published — ignored by design
            s = int(p.name.split("_")[1])
            tree = ck.restore_dict(s)
            np.testing.assert_array_equal(
                tree["bank"], np.full((512, 512), s, np.float32))
            np.testing.assert_array_equal(tree["meta"]["step"],
                                          np.arange(s + 1))


# ---------------------------------------------------------------------------
# Straggler heartbeats (S3)
# ---------------------------------------------------------------------------

class TestStragglerHeartbeats:
    def test_observe_strike_and_evict_policy(self):
        mon = StragglerMonitor(n_hosts=1, deadline_factor=2.0,
                               min_deadline_s=0.0, evict_after=3)
        for _ in range(16):
            v = mon.observe(0, 0.01)
            assert v["stragglers"] == []
        for strike in range(1, 3):
            v = mon.observe(0, 1.0)  # 100x the median: straggler
            assert v["stragglers"] == [0]
            assert mon.flagged[0] == strike and v["evict"] == []
        v = mon.observe(0, 1.0)
        assert v["evict"] == [0]  # third consecutive strike
        v = mon.observe(0, 0.01)  # recovery resets the strikes
        assert v["stragglers"] == [] and mon.flagged[0] == 0

    def test_observe_deadline_tracks_rolling_median(self):
        mon = StragglerMonitor(n_hosts=1, deadline_factor=2.0,
                               min_deadline_s=0.0)
        for _ in range(8):
            mon.observe(0, 0.010)
        v = mon.observe(0, 0.012)  # within 2x median: fine
        assert v["stragglers"] == [] and v["deadline_s"] == \
            pytest.approx(0.020)

    def test_scheduler_ticks_heartbeat_into_monitor(self, no_mesh):
        svc = HybridService.from_spec(_spec(slots=8))
        protos = _populate(svc, classes=(40,))
        svc.serve(_requests(protos, per_tenant=24))
        sched = svc.scheduler
        assert len(sched.monitor.history) == sched.stats.ticks > 0
        assert sched.last_verdict is not None
        assert {"deadline_s", "stragglers", "evict"} <= \
            set(sched.last_verdict)
        assert sched.stats.tick_time_s > 0.0
        m = svc.metrics()
        assert m["tick_time_s"] > 0.0 and "slow_ticks" in m
        h = svc.health()
        assert {"queue_depth", "load_shedding", "slow_ticks",
                "straggler_strikes", "evict_verdict"} <= set(h)

    def test_monitor_survives_scheduler_rebuild(self, no_mesh):
        svc = HybridService.from_spec(_spec(slots=8))
        protos = _populate(svc, classes=(40,))
        svc.serve(_requests(protos, per_tenant=16))
        hist = len(svc.scheduler.monitor.history)
        assert hist > 0
        svc.reconfigure(svc.spec._replace(scheduler=SchedulerSpec(slots=4)))
        assert len(svc.scheduler.monitor.history) == hist  # carried over
        svc.serve(_requests(protos, per_tenant=8))
        assert len(svc.scheduler.monitor.history) > hist


# ---------------------------------------------------------------------------
# Overload policy: deadlines + load shedding
# ---------------------------------------------------------------------------

class TestOverloadPolicy:
    def test_spec_validates_overload_thresholds(self):
        with pytest.raises(ValueError, match="deadline_ms"):
            _spec(deadline_ms=0.0).validate()
        with pytest.raises(ValueError, match="shed_queue"):
            _spec(shed_queue=0).validate()
        with pytest.raises(ValueError, match="shed_queue"):
            _spec(shed_queue=5000, max_queue=4096).validate()
        with pytest.raises(ValueError, match="shed_p99_ms"):
            _spec(shed_p99_ms=-1.0).validate()
        _spec(deadline_ms=50.0, shed_queue=8, shed_p99_ms=100.0).validate()

    def test_overload_fields_json_roundtrip(self):
        spec = _spec(deadline_ms=50.0, shed_queue=8, shed_p99_ms=100.0)
        assert ServiceSpec.from_json(spec.to_json()) == spec

    def test_shed_mode_answers_from_acam_alone(self, no_mesh):
        # tau = N: every request's margin is below it, so every request
        # WANTS escalation — overload must answer them at the ACAM anyway
        svc = HybridService.from_spec(_spec(slots=8, tau=float(N),
                                            shed_queue=8))
        protos = _populate(svc, classes=(40,))
        reqs = _requests(protos, per_tenant=24)
        for r in reqs:
            svc.submit(r)
        assert svc.overloaded() and svc.health()["load_shedding"]
        shed_resp = svc.step()
        assert all(r.shed and not r.escalated for r in shed_resp)
        # shed answers carry E_backend only — no front-end energy charged
        assert all(r.energy_j < svc._frontend_j for r in shed_resp)
        svc.drain()
        m = svc.metrics()
        assert m["shed"] >= len(shed_resp) > 0
        assert m["load_shed_ticks"] >= 1 and m["shed_rate"] > 0
        # below the threshold the cascade escalates again
        assert not svc.overloaded()
        resp = svc.serve(reqs[:4])
        assert all(r.escalated and not r.shed for r in resp)

    def test_deadline_expires_stale_queue(self, no_mesh):
        svc = HybridService.from_spec(_spec(slots=8, deadline_ms=30.0))
        protos = _populate(svc, classes=(40,))
        reqs = _requests(protos, per_tenant=8)
        for r in reqs[:6]:
            svc.submit(r)
        time.sleep(0.06)  # everything queued is now past the deadline
        svc.submit(reqs[6])  # ...except this fresh one
        resp = svc.step()
        expired = [r for r in resp if r.error is not None]
        served = [r for r in resp if r.error is None]
        assert len(expired) == 6 and len(served) == 1
        assert all("deadline exceeded" in r.error for r in expired)
        assert all(r.pred == -1 for r in expired)
        assert svc.metrics()["expired"] == 6
        assert svc.scheduler.qsize == 0

    def test_no_deadline_means_no_expiry(self, no_mesh):
        svc = HybridService.from_spec(_spec(slots=8))
        protos = _populate(svc, classes=(40,))
        for r in _requests(protos, per_tenant=4)[:4]:
            svc.submit(r)
        time.sleep(0.02)
        assert all(r.error is None for r in svc.drain())


# ---------------------------------------------------------------------------
# Durable service state (in-process)
# ---------------------------------------------------------------------------

class TestSnapshotRestore:
    def _boot(self, spec=None):
        svc = HybridService.from_spec(spec or _spec())
        protos = _populate(svc)
        return svc, protos

    def test_restore_bit_identical_zero_reregistrations(
            self, tmp_path, no_mesh, monkeypatch):
        svc, protos = self._boot()
        reqs = _requests(protos)
        before = _signature(svc.serve(reqs))
        ck = Checkpointer(tmp_path)
        step = svc.snapshot(ck)
        assert step == 0

        calls = {"n": 0}
        orig = TemplateBankRegistry.register

        def counting(self, *a, **kw):
            calls["n"] += 1
            return orig(self, *a, **kw)

        monkeypatch.setattr(TemplateBankRegistry, "register", counting)
        restored, report = HybridService.restore(ck)
        assert calls["n"] == 0, "restore must adopt placements, not re-register"
        assert report.step == 0 and report.tenants == 4
        assert not report.resharded
        assert _signature(restored.serve(reqs)) == before
        # head tables + taus survived: escalations in the signature already
        # prove it, but the head readback must match too
        np.testing.assert_array_equal(restored.head_of("t1")[0],
                                      svc.head_of("t1")[0])

    def test_restore_onto_shrunk_shards(self, tmp_path, no_mesh):
        svc, protos = self._boot(_spec(bank_shards=2))
        reqs = _requests(protos)
        before = _signature(svc.serve(reqs))
        ck = Checkpointer(tmp_path)
        svc.snapshot(ck)
        restored, report = HybridService.restore(
            ck, mesh=MeshSpec(bank_shards=1, install=False))
        assert report.resharded
        assert restored.registry.bank_shards == 1
        assert restored.spec.mesh.bank_shards == 1
        assert any("resharded" in a for a in report.actions)
        assert _signature(restored.serve(reqs)) == before

    def test_snapshot_steps_sequence_across_restarts(self, tmp_path,
                                                     no_mesh):
        svc, _ = self._boot()
        ck = Checkpointer(tmp_path)
        assert svc.snapshot(ck) == 0
        assert svc.snapshot(ck) == 1
        restored, _ = HybridService.restore(ck)
        assert restored.snapshot(ck) == 2  # continues, never overwrites

    def test_restore_empty_dir_raises(self, tmp_path, no_mesh):
        with pytest.raises(SnapshotError, match="no complete snapshot"):
            HybridService.restore(Checkpointer(tmp_path))

    def test_snapshot_is_async_capable(self, tmp_path, no_mesh):
        svc, protos = self._boot()
        reqs = _requests(protos)
        before = _signature(svc.serve(reqs))
        ck = Checkpointer(tmp_path)
        svc.snapshot(ck, blocking=False)
        # mutate AFTER the async handoff: the snapshot took copies
        svc.evict_tenant("t3")
        ck.wait()
        restored, report = HybridService.restore(ck)
        assert report.tenants == 4  # pre-evict state was captured
        assert _signature(restored.serve(reqs)) == before


# ---------------------------------------------------------------------------
# Trace harness
# ---------------------------------------------------------------------------

class TestTraceHarness:
    def test_trace_is_deterministic(self):
        tr = _traces()
        cfg = tr.TraceConfig(seed=3, requests=200, churn_every=2)
        assert tr.make_trace(cfg) == tr.make_trace(cfg)
        assert tr.make_trace(cfg) != tr.make_trace(
            tr.TraceConfig(seed=4, requests=200, churn_every=2))

    def test_zipf_popularity_is_skewed(self):
        tr = _traces()
        cfg = tr.TraceConfig(seed=0, tenants=8, requests=2000)
        counts = np.zeros(8)
        for op in tr.make_trace(cfg):
            if op[0] == "submit":
                counts[op[1]] += 1
        assert counts.sum() == 2000
        assert counts.max() > 3 * max(counts.min(), 1)

    def test_churn_ops_present_and_replayable(self, no_mesh):
        tr = _traces()
        cfg = tr.TraceConfig(seed=1, tenants=4, classes=10, num_features=N,
                             requests=96, burst=24, calm=4, phase_ticks=1,
                             churn_every=2)
        trace = tr.make_trace(cfg)
        kinds = [op[0] for op in trace]
        assert "evict" in kinds and "register" in kinds
        svc = HybridService.from_spec(_spec(slots=8))
        pool = tr.TenantPool(cfg)
        pool.register_all(svc)
        svc, stats = tr.replay(svc, trace, pool)
        assert stats["completed"] + svc.scheduler.qsize >= \
            stats["submitted"]
        assert stats["p99_burst_ms"] is not None

    def test_replay_kill_restores_and_finishes(self, tmp_path, no_mesh):
        tr = _traces()
        cfg = tr.TraceConfig(seed=2, tenants=4, classes=10, num_features=N,
                             requests=160, burst=32, calm=4, phase_ticks=1)
        svc = HybridService.from_spec(_spec(slots=8))
        pool = tr.TenantPool(cfg)
        pool.register_all(svc)
        ck = Checkpointer(tmp_path)
        chaos = tr.ChaosPlan(ckpt=ck, snapshot_every=2, kill_at_tick=3)
        svc, stats = tr.replay(svc, tr.make_trace(cfg), pool, chaos=chaos)
        assert stats["killed"] and stats["recovery_ms"] is not None
        assert stats["lost_in_flight"] > 0
        # the restored incarnation finished the trace...
        assert stats["completed"] > 0 and svc.scheduler.qsize == 0
        # ...and is bit-identical to a clean build on a fixed probe
        probe = [pool.request(t % 4, 555_000 + t) for t in range(32)]
        clean = HybridService.from_spec(_spec(slots=8))
        pool.register_all(clean)
        assert _signature(svc.serve(probe)) == \
            _signature(clean.serve(probe))

    def test_replay_device_loss_mid_stream(self, no_mesh):
        import jax

        if len(jax.devices()) < 2:
            pytest.skip("needs >= 2 devices (REPRO_FORCE_MESH) to lose one")
        tr = _traces()
        cfg = tr.TraceConfig(seed=5, tenants=4, classes=10, num_features=N,
                             requests=96, burst=24, calm=4, phase_ticks=1)
        svc = HybridService.from_spec(_spec(slots=8))
        pool = tr.TenantPool(cfg)
        pool.register_all(svc)
        chaos = tr.ChaosPlan(lose_devices_at=2, lose=(0,))
        svc, stats = tr.replay(svc, tr.make_trace(cfg), pool, chaos=chaos)
        assert stats["device_loss_downtime_ms"] is not None
        assert stats["completed"] > 0 and svc.scheduler.qsize == 0


# ---------------------------------------------------------------------------
# Forced 2x2 mesh: kill/restore across real process boundaries (S4)
# ---------------------------------------------------------------------------

def run_sub(code: str, timeout=600) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["JAX_PLATFORMS"] = "cpu"
    env.pop("XLA_FLAGS", None)
    env.pop("REPRO_FORCE_MESH", None)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


_CHILD_COMMON = """
    import os
    os.environ["XLA_FLAGS"] = \\
        "--xla_force_host_platform_device_count={ndev}"
    import json
    import numpy as np
    from repro.checkpoint.checkpointer import Checkpointer
    from repro.match.config import EngineConfig
    from repro.serve.acam_service import (ClassifyRequest,
                                          make_synthetic_tenant,
                                          sample_tenant_queries)
    from repro.serve.control import HybridService
    from repro.serve.spec import (CascadeSpec, MeshSpec, RegistrySpec,
                                  SchedulerSpec, ServiceSpec)

    N = 64

    def requests_and_protos():
        reqs = []
        for t in range(4):
            _, _, p = make_synthetic_tenant(1000 + 17 * t, num_classes=40,
                                            num_features=N)
            f, _ = sample_tenant_queries(7 + t, p, 30, noise=0.9)
            reqs += [(f"t{{t}}", f[j]) for j in range(30)]
        return reqs

    def serve_signature(svc):
        resp = svc.serve([ClassifyRequest(tid, f)
                          for tid, f in requests_and_protos()])
        return [[r.tenant_id, r.pred, bool(r.escalated),
                 round(r.margin, 6)] for r in resp]
"""


def _child(ndev: int, extra: str) -> str:
    """Compose a child script: the common harness + a test body, each
    dedented (they carry different literal indentation)."""
    return (textwrap.dedent(_CHILD_COMMON).format(ndev=ndev)
            + textwrap.dedent(extra))


class TestForcedMeshResilience:
    """The S4 acceptance test: populate + snapshot on a forced 2x2 mesh in
    one process, SIGKILL-equivalent (process exits), restore in a FRESH
    process — same mesh and shrunk mesh — with bit-identical serving and
    zero re-registrations."""

    def _snapshot_in_proc_a(self, tmp_path) -> list:
        """Process A: sharded service, serve, snapshot, die."""
        out = run_sub(_child(4, f"""
            spec = ServiceSpec(
                registry=RegistrySpec(num_features=N, initial_classes=256),
                engine=EngineConfig(backend="reference", margin=True),
                mesh=MeshSpec(bank_shards=2, install=True),
                scheduler=SchedulerSpec(slots=16),
                cascade=CascadeSpec(tau=6.0, tau_units="count"),
            )
            svc = HybridService.from_spec(spec)
            for t in range(4):
                bank, head, _ = make_synthetic_tenant(
                    1000 + 17 * t, num_classes=40, num_features=N)
                svc.register_tenant(f"t{{t}}", bank, head=head)
            sig = serve_signature(svc)
            svc.snapshot(Checkpointer({str(tmp_path)!r}))
            print("SIG", json.dumps(sig))
        """))
        for line in out.splitlines():
            if line.startswith("SIG "):
                return json.loads(line[4:])
        raise AssertionError(f"no signature in proc A output:\n{out}")

    def test_kill_and_restore_same_mesh_bit_identity(self, tmp_path):
        sig_a = self._snapshot_in_proc_a(tmp_path)
        assert any(s[2] for s in sig_a), "probe never escalates; weak test"
        # process B: fresh interpreter, fresh jax, same forced mesh
        out = run_sub(_child(4, f"""
            from repro.serve.registry import TemplateBankRegistry
            calls = {{"n": 0}}
            orig = TemplateBankRegistry.register
            def counting(self, *a, **kw):
                calls["n"] += 1
                return orig(self, *a, **kw)
            TemplateBankRegistry.register = counting
            svc, report = HybridService.restore(
                Checkpointer({str(tmp_path)!r}))
            assert calls["n"] == 0, "restore re-registered tenants"
            assert report.tenants == 4 and not report.resharded
            assert svc.registry.bank_shards == 2
            import jax
            assert len(jax.devices()) == 4
            from repro import match
            assert match.bank_shards_in_mesh() == 2  # mesh reinstalled
            print("SIG", json.dumps(serve_signature(svc)))
        """))
        sig_b = [json.loads(li[4:]) for li in out.splitlines()
                 if li.startswith("SIG ")][0]
        assert sig_b == sig_a, \
            "restore across processes changed preds/margins/escalations"

    def test_kill_and_restore_onto_shrunk_mesh(self, tmp_path):
        sig_a = self._snapshot_in_proc_a(tmp_path)
        # process C: only 2 devices survive the restart -> restore onto a
        # 1-shard mesh (elastic shrink across the crash)
        out = run_sub(_child(2, f"""
            svc, report = HybridService.restore(
                Checkpointer({str(tmp_path)!r}),
                mesh=MeshSpec(bank_shards=1, install=True))
            assert report.resharded
            assert svc.registry.bank_shards == 1
            assert any("resharded" in a for a in report.actions)
            print("SIG", json.dumps(serve_signature(svc)))
        """))
        sig_c = [json.loads(li[4:]) for li in out.splitlines()
                 if li.startswith("SIG ")][0]
        assert sig_c == sig_a, "shrunk-mesh restore changed served results"

    def test_live_device_loss_resharding(self):
        out = run_sub(_child(4, """
            spec = ServiceSpec(
                registry=RegistrySpec(num_features=N, initial_classes=256),
                engine=EngineConfig(backend="reference", margin=True),
                mesh=MeshSpec(bank_shards=2, install=True),
                scheduler=SchedulerSpec(slots=16),
                cascade=CascadeSpec(tau=6.0, tau_units="count"),
            )
            svc = HybridService.from_spec(spec)
            for t in range(4):
                bank, head, _ = make_synthetic_tenant(
                    1000 + 17 * t, num_classes=40, num_features=N)
                svc.register_tenant(f"t{t}", bank, head=head)
            before = serve_signature(svc)

            # lose one device: 3 survivors can only form 1 shard
            report = svc.handle_device_loss([3])
            assert svc.registry.bank_shards == 1
            assert any("device loss" in a for a in report.actions)
            assert serve_signature(svc) == before, "degraded != healthy"

            # heal, then lose two: 2 survivors keep bank_shards=1
            svc.restore_devices()
            svc.handle_device_loss([0, 1])
            assert serve_signature(svc) == before
            print("OK device loss")
        """))
        assert "OK device loss" in out
