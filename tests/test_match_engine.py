"""The `repro.match` engine layer (PR 3).

Covers the contracts the multi-layer refactor introduced:

  * engine API: hashable `EngineConfig`, memoised `engine_for`, backend
    registry validation, `use_backend` scoping + env parity;
  * the `set_backend` trace-time footgun fix: `hybrid._fused_forward`
    takes the backend as a *static* jit argument, so changing the default
    between `predict` calls produces a fresh trace (observable via the jit
    cache) instead of silently replaying the old executable;
  * device-backend parity: at `sigma_program = 0` the RRAM-physics backend
    reproduces the reference backend's classify decisions exactly through
    the engine API (both cell flavours), and `acam.soft_sense` stays
    finite/flowing under grad through the `program_bank` bridge;
  * mesh sharding: on a forced 2-device CPU mesh the engine shards the
    batch over the dp axes (queries carry a P(dp) spec; outputs come back
    dp-sharded) and classify output is bit-identical to single-device for
    B in {256, 1024}, for the hybrid classifier and the serving scheduler
    alike (subprocess, XLA_FLAGS must predate jax import).
"""
import functools
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import match
from repro.core import acam, hybrid, matching
from repro.core import templates as templates_lib
from repro.core.templates import TemplateBank

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _bank(key, c=6, k=2, n=64) -> TemplateBank:
    tmpl = (jax.random.uniform(key, (c, k, n)) > 0.5).astype(jnp.float32)
    lo = (jax.random.uniform(jax.random.fold_in(key, 1), (c, k, n)) > 0.6
          ).astype(jnp.float32)
    hi = jnp.maximum(lo, (jax.random.uniform(jax.random.fold_in(key, 2),
                                             (c, k, n)) > 0.4
                          ).astype(jnp.float32))
    valid = jnp.ones((c, k), bool)
    if k > 1:
        valid = valid.at[0, k - 1].set(False).at[c - 1, 0].set(False)
    thr = jax.random.normal(jax.random.fold_in(key, 3), (n,)) * 0.1
    return TemplateBank(tmpl, lo, hi, valid, thr)


class TestEngineAPI:
    def test_engine_for_memoised(self):
        e1 = match.engine_for(method="feature_count", backend="kernel")
        e2 = match.engine_for(method="feature_count", backend="kernel")
        assert e1 is e2
        assert e1 is not match.engine_for(method="similarity",
                                          backend="kernel")

    def test_config_hashable_and_static_jittable(self):
        cfg = match.EngineConfig(backend="reference",
                                 device=acam.ACAMConfig(sigma_program=0.1))
        assert hash(cfg) == hash(match.EngineConfig(
            backend="reference", device=acam.ACAMConfig(sigma_program=0.1)))

        # the whole point of EngineConfig: it works as a static jit arg
        calls = []

        @functools.partial(jax.jit, static_argnames=("cfg",))
        def by_config(x, cfg):
            calls.append(cfg)
            return x + 1

        by_config(jnp.zeros(2), cfg)
        by_config(jnp.zeros(2), cfg)  # cache hit: no retrace
        assert len(calls) == 1
        by_config(jnp.zeros(2), cfg._replace(backend="kernel"))
        assert len(calls) == 2  # different config -> different trace

    def test_unknown_backend_and_method_raise(self):
        with pytest.raises(ValueError):
            match.MatchEngine(match.EngineConfig(backend="cuda"))
        with pytest.raises(ValueError):
            match.MatchEngine(match.EngineConfig(method="cosine"))
        with pytest.raises(ValueError):
            match.engine_for(backend="gpuuu")

    def test_registry_lists_first_class_backends(self):
        names = match.backend_names()
        assert {"reference", "kernel", "device"} <= set(names)
        with pytest.raises(ValueError):
            match.register_backend("auto", lambda cfg: None)

    def test_use_backend_scopes_and_restores(self):
        before = match.default_backend()
        with match.use_backend("reference"):
            assert match.default_backend() == "reference"
            assert matching.get_backend() == "reference"  # shim parity
            with match.use_backend("kernel"):
                assert match.default_backend() == "kernel"
            assert match.default_backend() == "reference"
        assert match.default_backend() == before

    def test_auto_policy_tiny_vs_large(self):
        eng = match.engine_for(backend="auto")
        assert eng.backend(match.TINY_ELEMENTS - 1).name == "reference"
        assert eng.backend(match.TINY_ELEMENTS).name == "kernel"

    def test_margin_config_directed_call(self):
        key = jax.random.PRNGKey(0)
        bank = _bank(key)
        feats = jax.random.normal(jax.random.fold_in(key, 4), (8, 64))
        plain = match.engine_for(backend="reference")
        with_m = match.engine_for(backend="reference", margin=True)
        assert len(plain(feats, bank)) == 2
        pred, per_class, margin = with_m(feats, bank)
        assert margin.shape == (8,)
        np.testing.assert_array_equal(np.asarray(pred),
                                      np.asarray(plain(feats, bank)[0]))


class TestFusedForwardRetrace:
    """Satellite: the `set_backend` trace-time baking footgun is fixed."""

    def test_backend_change_retraces_fused_forward(self):
        key = jax.random.PRNGKey(1)
        x = jax.random.normal(key, (32, 64))
        y = jnp.arange(32) % 4
        bank = templates_lib.generate_templates(x, y, 4, k=1)
        clf = hybrid.HybridClassifier(None, lambda p, q: q,
                                      hybrid.ACAMHead(bank=bank))
        with match.use_backend("reference"):
            p_ref = clf.predict(x)
            size_ref = hybrid._fused_forward._cache_size()
            # same backend again: cache hit, no new trace
            clf.predict(x)
            assert hybrid._fused_forward._cache_size() == size_ref
        with match.use_backend("kernel"):
            # the backend is a static jit argument resolved at call time:
            # a changed default MUST key a different executable
            p_ker = clf.predict(x)
            assert hybrid._fused_forward._cache_size() == size_ref + 1
        np.testing.assert_array_equal(np.asarray(p_ref), np.asarray(p_ker))

    def test_head_backend_field_pins_over_default(self):
        key = jax.random.PRNGKey(2)
        x = jax.random.normal(key, (16, 64))
        y = jnp.arange(16) % 4
        bank = templates_lib.generate_templates(x, y, 4, k=1)
        head = hybrid.ACAMHead(bank=bank, backend="reference")
        assert head.engine().config.backend == "reference"
        with match.use_backend("kernel"):
            assert head.engine().config.backend == "reference"


class TestDeviceBackendParity:
    """Satellite: the acam.py physics models through the engine API."""

    @pytest.mark.parametrize("cell", ["6T4R", "3T1R"])
    def test_decisions_match_reference_at_sigma_zero(self, cell):
        key = jax.random.PRNGKey(3)
        bank = _bank(key, c=10, k=2, n=128)
        feats = jax.random.normal(jax.random.fold_in(key, 5), (37, 128))
        dev = match.engine_for(backend="device",
                               device=acam.ACAMConfig(cell=cell,
                                                      sigma_program=0.0))
        ref = match.engine_for(backend="reference")
        pred_d, pc_d = dev.classify_features(feats, bank)
        pred_r, pc_r = ref.classify_features(feats, bank)
        np.testing.assert_array_equal(np.asarray(pred_d), np.asarray(pred_r))
        # device scores are matchline fractions: count / N exactly at
        # sigma=0 (valid rows; invalid stay -inf on both backends)
        finite = np.isfinite(np.asarray(pc_r))
        np.testing.assert_allclose(np.asarray(pc_d)[finite],
                                   np.asarray(pc_r)[finite] / 128.0,
                                   rtol=1e-5, atol=1e-6)

    def test_margins_are_fraction_scaled(self):
        key = jax.random.PRNGKey(4)
        bank = _bank(key, c=8, k=1, n=64)
        feats = jax.random.normal(jax.random.fold_in(key, 6), (16, 64))
        dev = match.engine_for(backend="device")
        ref = match.engine_for(backend="reference")
        pred_d, _, m_d = dev.classify_features_margin(feats, bank)
        pred_r, _, m_r = ref.classify_features_margin(feats, bank)
        np.testing.assert_array_equal(np.asarray(pred_d), np.asarray(pred_r))
        np.testing.assert_allclose(np.asarray(m_d), np.asarray(m_r) / 64.0,
                                   rtol=1e-5, atol=1e-6)

    def test_similarity_alpha_zero_matches_reference(self):
        # at alpha=0 the reference similarity is the pure Eq. 10 in-window
        # fraction — exactly what the matchline senses
        key = jax.random.PRNGKey(5)
        bank = _bank(key, c=6, k=2, n=96)
        q = (jax.random.uniform(jax.random.fold_in(key, 7), (21, 96)) > 0.5
             ).astype(jnp.float32)
        dev = match.engine_for(method="similarity", alpha=0.0,
                               backend="device")
        ref = match.engine_for(method="similarity", alpha=0.0,
                               backend="reference")
        pred_d, _ = dev.classify(q, bank)
        pred_r, _ = ref.classify(q, bank)
        np.testing.assert_array_equal(np.asarray(pred_d), np.asarray(pred_r))

    def test_sigma_program_perturbs_through_engine(self):
        key = jax.random.PRNGKey(6)
        bank = _bank(key, c=6, k=1, n=64)
        feats = jax.random.normal(jax.random.fold_in(key, 8), (64, 64))
        ideal = match.engine_for(backend="device")
        noisy = match.engine_for(
            backend="device",
            device=acam.ACAMConfig(sigma_program=0.5), seed=11)
        _, pc_i = ideal.classify_features(feats, bank)
        _, pc_n = noisy.classify_features(feats, bank)
        assert not np.allclose(np.asarray(pc_i), np.asarray(pc_n))
        # deterministic per seed
        _, pc_n2 = match.engine_for(
            backend="device",
            device=acam.ACAMConfig(sigma_program=0.5),
            seed=11).classify_features(feats, bank)
        np.testing.assert_array_equal(np.asarray(pc_n), np.asarray(pc_n2))

    def test_service_resolves_default_device_backend_for_tau(self):
        """ACAMService(backend=None) under a process default of "device"
        must rescale margin_tau exactly like a pinned backend="device"
        service — otherwise count-unit taus meet fraction-unit margins and
        the cascade silently escalates everything."""
        from repro.serve import acam_service as svc_lib

        def build(backend):
            svc = svc_lib.ACAMService(
                64, config=svc_lib.ServiceConfig(slots=8), backend=backend)
            bank, head, p = svc_lib.make_synthetic_tenant(
                60, num_classes=6, num_features=64)
            svc.register_tenant("t", bank, head=head)
            return svc, p

        with match.use_backend("device"):
            svc_default, protos = build(None)
        svc_pinned, _ = build("device")
        feats, _ = svc_lib.sample_tenant_queries(2, protos, 24, noise=0.9)
        reqs = [svc_lib.ClassifyRequest("t", feats[i]) for i in range(24)]
        r_default = svc_default.serve(list(reqs))
        r_pinned = svc_pinned.serve(list(reqs))
        assert [(r.pred, r.escalated) for r in r_default] == \
            [(r.pred, r.escalated) for r in r_pinned]
        assert not all(r.escalated for r in r_default)

    def test_soft_sense_grad_finite_through_program_bank(self):
        key = jax.random.PRNGKey(7)
        bank = _bank(key, c=4, k=1, n=32)
        feats = jax.random.uniform(jax.random.fold_in(key, 9), (12, 32))
        be = match.backend_for("device", match.EngineConfig(backend="device"))
        prog = be.program_bank(bank)

        def loss(bounds):
            lo, hi = bounds
            sim = acam.soft_sense(prog._replace(lower=lo, upper=hi), feats)
            return -jnp.mean(jax.nn.log_softmax(sim * 10.0, axis=-1)[:, 0])

        glo, ghi = jax.grad(loss)((prog.lower, prog.upper))
        for g in (glo, ghi):
            arr = np.asarray(g)
            assert np.all(np.isfinite(arr))
            assert np.abs(arr).max() > 0.0


class TestShardSpecs:
    """Unit-level: the engine's shard_map specs put the queries on the dp
    axes and replicate the bank."""

    def test_queries_are_dp_sharded(self):
        in_specs, out_specs = match.batch_specs(("data",), 3, (1, 2, 1))
        assert in_specs[0] == P(("data",))   # features
        assert in_specs[1] == P(("data",))   # class_lo
        assert in_specs[2] == P(("data",))   # class_hi
        assert in_specs[3] == P()            # bank: replicated
        assert out_specs[0] == P(("data",))
        assert out_specs[1] == P(("data",), None)

    def test_multi_axis_dp(self):
        in_specs, out_specs = match.batch_specs(("pod", "data"), 1, (2,))
        assert in_specs[0] == P(("pod", "data"))
        assert out_specs[0] == P(("pod", "data"), None)

    def test_no_mesh_means_no_sharding(self):
        from repro.distributed import context

        # save/restore: under REPRO_FORCE_MESH the suite runs with a mesh
        saved_axes, saved_mesh = context.get(), context.get_mesh()
        context.clear()
        try:
            assert match.dp_axes_in_mesh() == (None, None)
            plan, mesh = match.plan_for(batch=256, num_classes=128)
            assert plan is match.REPLICATED and mesh is None
        finally:
            if saved_axes is not None:
                context.set_mesh_axes(saved_axes.dp, saved_axes.model,
                                      saved_mesh)


class TestPartitionPlan:
    """Unit-level: plan derivation from mesh + static shapes (no devices
    needed — a (1, 1) host mesh exercises the code paths; the forced
    multi-device parity lives in tests/test_bank_sharding.py)."""

    def _with_mesh(self, shape):
        from repro.distributed import context

        mesh = jax.make_mesh(shape, ("data", "model"))
        context.set_mesh_axes("data", "model", mesh)
        return mesh

    def _restore(self, saved):
        from repro.distributed import context

        context.clear()
        if saved[0] is not None:
            context.set_mesh_axes(saved[0].dp, saved[0].model, saved[1])

    def test_plan_replicated_on_trivial_mesh(self):
        from repro.distributed import context

        saved = (context.get(), context.get_mesh())
        try:
            self._with_mesh((1, 1))
            plan, mesh = match.plan_for(batch=256, num_classes=128)
            assert plan is match.REPLICATED and mesh is None
        finally:
            self._restore(saved)

    def test_plan_is_hashable_and_specs(self):
        from jax.sharding import PartitionSpec as PS

        plan = match.PartitionPlan(dp=("data",), model="model",
                                   dp_devices=2, bank_shards=2,
                                   rows_per_shard=64)
        assert hash(plan) == hash(match.PartitionPlan(
            dp=("data",), model="model", dp_devices=2, bank_shards=2,
            rows_per_shard=64))
        assert plan.batch_sharded and plan.bank_sharded and plan.sharded
        assert plan.batch_spec() == PS(("data",))
        assert plan.class_spec() == PS("model")
        assert plan.batch_class_spec(3) == PS(("data",), "model", None)
        bank_sp = match.bank_specs(plan)
        assert bank_sp.templates == PS("model")
        assert bank_sp.thresholds == PS()

    def test_non_divisible_shapes_stay_replicated_axes(self):
        plan = match.PartitionPlan()
        assert not plan.sharded
        assert plan.batch_spec() == jax.sharding.PartitionSpec(None)

    def test_bank_shards_in_mesh(self):
        from repro.distributed import context

        saved = (context.get(), context.get_mesh())
        try:
            context.clear()
            assert match.bank_shards_in_mesh() == 1
            self._with_mesh((1, 1))
            assert match.bank_shards_in_mesh() == 1
        finally:
            self._restore(saved)


class TestMeshGenerationRetrace:
    """Satellite: installing/clearing a mesh re-traces jitted callers that
    bake the engine's PartitionPlan (mirrors the use_backend retrace test —
    a (1, 1) mesh never shards, so only the static mesh_gen key changes)."""

    def test_mesh_change_retraces_fused_forward(self):
        from repro.distributed import context

        key = jax.random.PRNGKey(21)
        x = jax.random.normal(key, (32, 64))
        y = jnp.arange(32) % 4
        bank = templates_lib.generate_templates(x, y, 4, k=1)
        clf = hybrid.HybridClassifier(None, lambda p, q: q,
                                      hybrid.ACAMHead(bank=bank,
                                                      backend="reference"))
        saved = (context.get(), context.get_mesh())
        try:
            p0 = clf.predict(x)
            size0 = hybrid._fused_forward._cache_size()
            clf.predict(x)  # same generation: cache hit
            assert hybrid._fused_forward._cache_size() == size0
            mesh = jax.make_mesh((1, 1), ("data", "model"))
            context.set_mesh_axes("data", "model", mesh)
            p1 = clf.predict(x)
            assert hybrid._fused_forward._cache_size() == size0 + 1
            context.clear()
            p2 = clf.predict(x)
            assert hybrid._fused_forward._cache_size() == size0 + 2
            np.testing.assert_array_equal(np.asarray(p0), np.asarray(p1))
            np.testing.assert_array_equal(np.asarray(p0), np.asarray(p2))
        finally:
            context.clear()
            if saved[0] is not None:
                context.set_mesh_axes(saved[0].dp, saved[0].model, saved[1])

    def test_mesh_change_retraces_scheduler_tick(self):
        import time

        from repro.distributed import context
        from repro.serve import acam_service as svc_lib
        from repro.serve import scheduler as sched_lib

        saved = (context.get(), context.get_mesh())

        def tick_once(sched, feats):
            sched.submit(sched_lib.WorkItem(0, "t", feats,
                                            time.perf_counter()))
            return [(r.pred_local, round(r.margin, 6)) for r in sched.tick()]

        try:
            bank, _, protos = svc_lib.make_synthetic_tenant(
                77, num_classes=6, num_features=64)
            from repro.serve.registry import TemplateBankRegistry

            reg = TemplateBankRegistry(64)
            reg.register("t", bank)
            sched = sched_lib.MicroBatchScheduler(reg, slots=4,
                                                  backend="reference")
            feats, _ = svc_lib.sample_tenant_queries(3, protos, 1)
            out0 = tick_once(sched, feats[0])
            size0 = sched_lib._batched_classify._cache_size()
            tick_once(sched, feats[0])  # same generation: cache hit
            assert sched_lib._batched_classify._cache_size() == size0
            mesh = jax.make_mesh((1, 1), ("data", "model"))
            context.set_mesh_axes("data", "model", mesh)
            out1 = tick_once(sched, feats[0])
            # mesh_gen is a static jit arg: a new mesh keys a fresh trace
            assert sched_lib._batched_classify._cache_size() == size0 + 1
            assert out1 == out0
        finally:
            context.clear()
            if saved[0] is not None:
                context.set_mesh_axes(saved[0].dp, saved[0].model, saved[1])


class TestSweepProgramNoise:
    """Satellite: Monte-Carlo programming-noise sweep through the engine."""

    def test_per_key_predictions_shape_and_determinism(self):
        key = jax.random.PRNGKey(31)
        bank = _bank(key, c=6, k=1, n=64)
        feats = jax.random.normal(jax.random.fold_in(key, 1), (40, 64))
        eng = match.engine_for(
            backend="device", device=acam.ACAMConfig(sigma_program=0.4),
            seed=5)
        pred, per_class = eng.sweep_program_noise(feats, bank, 4)
        assert pred.shape == (4, 40)
        assert per_class.shape == (4, 40, 6)
        # draws differ between keys...
        accs = np.asarray(per_class)
        assert not np.allclose(accs[0], accs[1])
        # ...and the sweep is deterministic per config seed
        pred2, _ = eng.sweep_program_noise(feats, bank, 4)
        np.testing.assert_array_equal(np.asarray(pred), np.asarray(pred2))

    def test_sigma_zero_draws_collapse_to_ideal(self):
        key = jax.random.PRNGKey(32)
        bank = _bank(key, c=5, k=1, n=32)
        feats = jax.random.normal(jax.random.fold_in(key, 2), (16, 32))
        eng = match.engine_for(backend="device")
        pred, per_class = eng.sweep_program_noise(feats, bank, 3)
        ideal_pred, ideal_pc = eng.classify_features(feats, bank)
        for m in range(3):
            np.testing.assert_array_equal(np.asarray(pred[m]),
                                          np.asarray(ideal_pred))
            np.testing.assert_allclose(np.asarray(per_class[m]),
                                       np.asarray(ideal_pc), rtol=1e-6)

    def test_explicit_keys_and_backend_guard(self):
        key = jax.random.PRNGKey(33)
        bank = _bank(key, c=4, k=1, n=32)
        feats = jax.random.normal(jax.random.fold_in(key, 3), (8, 32))
        eng = match.engine_for(
            backend="device", device=acam.ACAMConfig(sigma_program=0.2))
        keys = jax.random.split(jax.random.PRNGKey(7), 5)
        pred, _ = eng.sweep_program_noise(feats, bank, keys)
        assert pred.shape == (5, 8)
        with pytest.raises(ValueError):
            match.engine_for(backend="kernel").sweep_program_noise(
                feats, bank, 2)

    def test_per_shard_noise_is_a_distinct_deterministic_semantics(self):
        """Satellite: `device_noise="per_shard"` programs one array per
        bank shard (fold_in(seed, s)) — the sweep covers the tiled layout
        without a mesh via `bank_shards=S` emulation."""
        key = jax.random.PRNGKey(34)
        bank = _bank(key, c=8, k=1, n=32)
        feats = jax.random.normal(jax.random.fold_in(key, 4), (20, 32))
        dev = acam.ACAMConfig(sigma_program=0.3)
        tiled = match.engine_for(backend="device", device=dev, seed=5,
                                 device_noise="per_shard")
        mono = match.engine_for(backend="device", device=dev, seed=5)
        # per-shard noise lifts the backend's bank-sharding refusal
        assert tiled.backend(None).supports_bank_sharding
        assert not mono.backend(None).supports_bank_sharding
        _, pc2 = tiled.sweep_program_noise(feats, bank, 3, bank_shards=2)
        _, pc2b = tiled.sweep_program_noise(feats, bank, 3, bank_shards=2)
        np.testing.assert_array_equal(np.asarray(pc2), np.asarray(pc2b))
        # a 2-array tiling realises a different noise field than 1 array
        _, pc1 = tiled.sweep_program_noise(feats, bank, 3, bank_shards=1)
        assert not np.allclose(np.asarray(pc1), np.asarray(pc2))
        # ...and than the "global" one-array semantics (fold_in vs raw key)
        _, pcg = mono.sweep_program_noise(feats, bank, 3)
        assert not np.allclose(np.asarray(pcg), np.asarray(pc2))
        # indivisible class counts fall back to one array, not an error
        _, pc_odd = tiled.sweep_program_noise(feats, bank, 3, bank_shards=3)
        np.testing.assert_array_equal(np.asarray(pc_odd), np.asarray(pc1))


def run_sub(code: str, timeout=600) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    # pin the CPU platform: without it jax probes the TPU runtime in this
    # container and stalls for minutes before falling back. XLA_FLAGS
    # (forced host device count) is set inside the child before jax import.
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


class TestMeshSharded:
    """Forced 2-device CPU mesh (subprocess: XLA_FLAGS precedes jax)."""

    def test_engine_bit_identical_and_dp_sharded_2dev(self):
        out = run_sub("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
            import jax, jax.numpy as jnp
            import numpy as np
            from repro import match
            from repro.core.templates import TemplateBank
            from repro.distributed import context

            key = jax.random.PRNGKey(0)
            c, k, n = 10, 2, 784
            tmpl = (jax.random.uniform(key, (c, k, n)) > 0.5
                    ).astype(jnp.float32)
            bank = TemplateBank(tmpl, jnp.zeros_like(tmpl),
                                jnp.ones_like(tmpl), jnp.ones((c, k), bool),
                                jnp.zeros((n,)))
            eng = match.engine_for(backend="kernel")
            eng_m = match.engine_for(backend="kernel", margin=True)

            for b in (256, 1024):
                feats = jax.random.normal(jax.random.fold_in(key, b), (b, n))
                lo = jnp.zeros((b,), jnp.int32)
                hi = jnp.full((b,), c, jnp.int32)

                context.clear()
                pred1, pc1 = eng.classify_features(feats, bank)
                p1, _, m1 = eng_m.classify_features_margin(feats, bank,
                                                           lo, hi)
                s1 = eng.scores(feats, bank)

                mesh = jax.make_mesh((2, 1), ("data", "model"))
                context.set_mesh_axes("data", "model", mesh)
                assert match.dp_axes_in_mesh()[1] == ("data",)
                pred2, pc2 = eng.classify_features(feats, bank)
                p2, _, m2 = eng_m.classify_features_margin(feats, bank,
                                                           lo, hi)
                s2 = eng.scores(feats, bank)
                context.clear()

                # outputs came back dp-sharded: the batch really ran
                # split across the two devices
                spec = pred2.sharding.spec
                assert tuple(spec)[:1] in ((("data",),), ("data",)), spec
                assert len(pred2.sharding.device_set) == 2

                assert np.array_equal(np.asarray(pred1), np.asarray(pred2))
                assert np.array_equal(np.asarray(pc1), np.asarray(pc2))
                assert np.array_equal(np.asarray(p1), np.asarray(p2))
                assert np.array_equal(np.asarray(m1), np.asarray(m2))
                assert np.array_equal(np.asarray(s1), np.asarray(s2))
                print("OK", b)
            """)
        assert out.count("OK") == 2

    def test_hybrid_predict_and_scheduler_2dev(self):
        out = run_sub("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
            import jax, jax.numpy as jnp
            import numpy as np
            from repro import match
            from repro.core import hybrid, templates
            from repro.distributed import context
            from repro.serve import acam_service as svc_lib

            key = jax.random.PRNGKey(0)
            x = jax.random.normal(key, (256, 64))
            y = jnp.arange(256) % 8
            bank = templates.generate_templates(x, y, 8, k=1)
            clf = hybrid.HybridClassifier(None, lambda p, q: q,
                                          hybrid.ACAMHead(bank=bank))

            def serve_once():
                svc = svc_lib.ACAMService(
                    64, config=svc_lib.ServiceConfig(slots=16))
                protos = {}
                for t in range(3):
                    b, h, p = svc_lib.make_synthetic_tenant(
                        50 + t, num_classes=6, num_features=64)
                    svc.register_tenant(f"t{t}", b, head=h)
                    protos[f"t{t}"] = p
                reqs = []
                for t in range(3):
                    f, _ = svc_lib.sample_tenant_queries(
                        9 + t, protos[f"t{t}"], 16)
                    reqs += [svc_lib.ClassifyRequest(f"t{t}", f[i])
                             for i in range(16)]
                rs = svc.serve(reqs)
                return [(r.pred, r.escalated) for r in rs]

            context.clear()
            pred1 = clf.predict(x)
            served1 = serve_once()

            mesh = jax.make_mesh((2, 1), ("data", "model"))
            context.set_mesh_axes("data", "model", mesh)
            pred2 = clf.predict(x)
            served2 = serve_once()
            context.clear()

            assert np.array_equal(np.asarray(pred1), np.asarray(pred2))
            assert served1 == served2
            print("OK hybrid+scheduler")
            """)
        assert "OK hybrid+scheduler" in out
