"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret mode).

Every Pallas kernel is swept over shapes (aligned + ragged, forcing the
padding paths) and dtypes, asserting against its ref.py oracle.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_fallback import given, settings, st

from repro.kernels.acam_match import ops as match_ops
from repro.kernels.acam_match.ref import acam_match_ref
from repro.kernels.acam_similarity import ops as sim_ops
from repro.kernels.acam_similarity.ref import acam_similarity_ref
from repro.kernels.kd_loss import ops as kd_ops
from repro.kernels.kd_loss.ref import kd_loss_ref
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention.ref import attention_ref


class TestAcamMatch:
    @pytest.mark.parametrize("b,m,n", [
        (8, 10, 784),      # the paper's deployment shape
        (128, 128, 512),   # exactly one tile
        (37, 30, 300),     # ragged: every dim padded
        (1, 1, 1),         # degenerate
        (200, 257, 1000),  # multi-tile ragged
    ])
    def test_shapes(self, b, m, n):
        key = jax.random.PRNGKey(b * m + n)
        f = jax.random.normal(key, (b, n))
        thr = jax.random.normal(jax.random.fold_in(key, 1), (n,)) * 0.1
        t = (jax.random.uniform(jax.random.fold_in(key, 2), (m, n)) > 0.5
             ).astype(jnp.float32)
        got = match_ops.match_scores(f, thr, t)
        np.testing.assert_allclose(got, acam_match_ref(f, thr, t), atol=0)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        key = jax.random.PRNGKey(0)
        f = jax.random.normal(key, (16, 256)).astype(dtype)
        thr = jnp.zeros((256,), dtype)
        t = (jax.random.uniform(jax.random.fold_in(key, 1), (12, 256)) > 0.5
             ).astype(dtype)
        got = match_ops.match_scores(f, thr, t)
        want = acam_match_ref(f.astype(jnp.float32), thr.astype(jnp.float32),
                              t.astype(jnp.float32))
        np.testing.assert_allclose(got, want, atol=0)

    def test_classify_matches_core(self):
        from repro.core import matching, quant, templates as T
        key = jax.random.PRNGKey(7)
        feats = jax.random.normal(key, (64, 96))
        labels = jnp.arange(64) % 4
        bank = T.generate_templates(feats, labels, 4, k=2)
        pred_kernel, _ = match_ops.classify(
            feats, bank.thresholds, bank.templates.reshape(8, 96),
            bank.valid.reshape(8), 4)
        q = quant.binarize(feats, bank.thresholds)
        pred_core, _ = matching.classify(q, bank, method="feature_count")
        assert bool(jnp.all(pred_kernel == pred_core))


class TestAcamSimilarity:
    @pytest.mark.parametrize("b,m,n,alpha", [
        (8, 128, 128, 1.0),
        (17, 9, 300, 2.0),
        (8, 10, 784, 0.5),
        (3, 2, 50, 1.0),
    ])
    def test_shapes(self, b, m, n, alpha):
        key = jax.random.PRNGKey(b + m + n)
        q = jax.random.uniform(key, (b, n))
        lo = jax.random.uniform(jax.random.fold_in(key, 1), (m, n)) * 0.5
        hi = lo + jax.random.uniform(jax.random.fold_in(key, 2), (m, n)) * 0.5
        got = sim_ops.similarity_scores(q, lo, hi, alpha=alpha)
        want = acam_similarity_ref(q, lo, hi, alpha=alpha)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    def test_scores_bounded(self):
        key = jax.random.PRNGKey(3)
        q = jax.random.uniform(key, (32, 100))
        lo = jnp.zeros((5, 100))
        hi = jnp.ones((5, 100))
        s = sim_ops.similarity_scores(q, lo, hi)
        assert bool(jnp.all((s >= 0) & (s <= 1)))
        np.testing.assert_allclose(s, 1.0)  # everything inside the window


class TestKDLoss:
    @pytest.mark.parametrize("b,v", [
        (13, 5000), (8, 152064 // 16), (256, 2048), (3, 17), (64, 504),
    ])
    def test_shapes(self, b, v):
        key = jax.random.PRNGKey(b + v)
        zs = jax.random.normal(key, (b, v)) * 3
        zt = jax.random.normal(jax.random.fold_in(key, 1), (b, v)) * 3
        y = jax.random.randint(jax.random.fold_in(key, 2), (b,), 0, v)
        got = kd_ops.distillation_loss(zs, zt, y)
        want = float(jnp.mean(kd_loss_ref(zs, zt, y)))
        assert float(got) == pytest.approx(want, rel=1e-4, abs=1e-5)

    @given(st.floats(1.0, 8.0), st.floats(0.0, 1.0))
    @settings(max_examples=10, deadline=None)
    def test_hyperparams(self, t, alpha):
        key = jax.random.PRNGKey(int(t * 10 + alpha * 100))
        zs = jax.random.normal(key, (6, 400)) * 2
        zt = jax.random.normal(jax.random.fold_in(key, 1), (6, 400)) * 2
        y = jnp.arange(6) * 7
        got = kd_ops.distillation_loss(zs, zt, y, temperature=t, alpha=alpha)
        want = float(jnp.mean(kd_loss_ref(zs, zt, y, temperature=t, alpha=alpha)))
        assert float(got) == pytest.approx(want, rel=1e-3, abs=1e-4)

    def test_matches_core_distill(self):
        from repro.core import distill
        key = jax.random.PRNGKey(0)
        zs = jax.random.normal(key, (32, 100))
        zt = jax.random.normal(jax.random.fold_in(key, 1), (32, 100))
        y = jnp.arange(32) % 100
        got = kd_ops.distillation_loss(zs, zt, y, temperature=4.0, alpha=0.5)
        want = distill.distillation_loss(zs, zt, y, alpha=0.5, temperature=4.0)
        assert float(got) == pytest.approx(float(want), rel=1e-4)


class TestFlashAttention:
    @pytest.mark.parametrize("b,s,h,kv,d,causal", [
        (2, 200, 8, 2, 64, True),
        (1, 128, 4, 4, 128, True),
        (2, 333, 6, 2, 64, False),   # ragged + bidirectional (encoder)
        (1, 512, 2, 1, 32, True),
    ])
    def test_against_ref(self, b, s, h, kv, d, causal):
        key = jax.random.PRNGKey(s + h)
        q = jax.random.normal(key, (b, s, h, d), jnp.float32)
        k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, kv, d))
        v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, kv, d))
        got = fa_ops.attention(q, k, v, causal=causal, block=(128, 128))
        g = h // kv
        kx, vx = jnp.repeat(k, g, axis=2), jnp.repeat(v, g, axis=2)
        q3 = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
        k3 = kx.transpose(0, 2, 1, 3).reshape(b * h, s, d)
        v3 = vx.transpose(0, 2, 1, 3).reshape(b * h, s, d)
        want = attention_ref(q3, k3, v3, causal=causal).reshape(
            b, h, s, d).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)

    def test_matches_model_fallback(self):
        """Kernel == the model's chunked XLA fallback (same semantics)."""
        from repro.models.layers import chunked_attention
        key = jax.random.PRNGKey(1)
        q = jax.random.normal(key, (2, 160, 4, 32))
        k = jax.random.normal(jax.random.fold_in(key, 1), (2, 160, 2, 32))
        v = jax.random.normal(jax.random.fold_in(key, 2), (2, 160, 2, 32))
        got = fa_ops.attention(q, k, v, causal=True, block=(64, 64))
        want = chunked_attention(q, k, v, causal=True, q_chunk=64)
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)
