"""Distribution-layer tests.

Sharding-rule unit tests run in-process on the host device (specs only, no
allocation). Multi-device behaviour (pjit train step on a real 8-device
mesh, dry-run lower+compile on the 512-device production mesh) runs in
subprocesses because XLA_FLAGS must be set before jax initialises.
"""
import os
import subprocess
import sys
import textwrap

import jax
import pytest
from jax.sharding import PartitionSpec as P

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str, timeout=600) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    # pin the CPU platform: without it jax probes the TPU runtime in this
    # container and stalls ~7 minutes per subprocess before falling back.
    # XLA_FLAGS (forced host device count) still applies under cpu.
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


class TestShardingRules:
    def _specs(self, arch="tinyllama-1.1b"):
        from repro import configs
        from repro.distributed import sharding
        # spec construction needs only mesh *shape* metadata; a 1-device
        # host is enough to build an abstract 16x16 mesh? No — use the
        # abstract mesh API via make_mesh on available devices:
        import numpy as np
        from jax.sharding import Mesh
        devs = np.asarray(jax.devices()[:1]).reshape(1, 1)
        mesh = Mesh(devs, ("data", "model"))
        cfg = configs.get(arch)
        return cfg, mesh, sharding

    def test_specs_cover_params(self):
        cfg, mesh, sharding = self._specs()
        import jax as j
        from repro.models import lm
        shapes = j.eval_shape(lambda: lm.init_params(j.random.PRNGKey(0), cfg))
        specs = sharding.param_specs(cfg, mesh, "tp")
        assert (j.tree_util.tree_structure(shapes)
                == j.tree_util.tree_structure(specs))

    def test_fit_spec_drops_nondivisible(self):
        cfg, mesh, sharding = self._specs()
        # mesh is 1x1 here; use a fake larger mesh via shape arithmetic:
        from jax.sharding import Mesh
        import numpy as np
        if jax.device_count() < 2:
            # fit against the 1-device mesh: everything divides
            s = sharding.fit_spec(P("model"), (7,), mesh)
            assert s == P("model")

    def test_dp_axes(self):
        from repro.distributed import sharding
        from jax.sharding import Mesh
        import numpy as np
        mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                    ("data", "model"))
        assert sharding.dp_axes(mesh) == "data"


class TestMultiDevice:
    """Real 8-device pjit execution (subprocess, forced host devices)."""

    def test_train_step_8dev(self):
        out = run_sub("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
            import jax, jax.numpy as jnp
            from repro import configs
            from repro.distributed import context as mesh_ctx, sharding
            from repro.launch import steps as steps_lib
            from repro.models import lm

            mesh = jax.make_mesh((2, 4), ("data", "model"))
            cfg = configs.get("qwen3-1.7b", smoke=True)
            mesh_ctx.set_mesh_axes("data", "model")
            with mesh:
                batch = {
                    "inputs": jnp.zeros((4, 32), jnp.int32),
                    "labels": jnp.zeros((4, 32), jnp.int32),
                }
                fn, in_sp, _, opt = steps_lib.build_train_step(
                    cfg, mesh, mode="fsdp_tp", example_batch=batch)
                params = lm.init_params(jax.random.PRNGKey(0), cfg)
                params = jax.device_put(params, sharding.to_shardings(
                    in_sp[0], mesh))
                opt_state = jax.device_put(opt.init(params),
                    sharding.to_shardings(in_sp[1], mesh))
                for _ in range(3):
                    params, opt_state, m = fn(params, opt_state, batch)
                print("LOSS", float(m["loss"]))
            """)
        loss = float(out.strip().split("LOSS")[-1])
        assert 0.0 < loss < 20.0

    def test_elastic_remesh_8dev(self):
        """Checkpoint on a (4,2) mesh restores onto (2,4) and keeps training."""
        out = run_sub("""
            import os, tempfile
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
            import jax, jax.numpy as jnp
            from repro import configs
            from repro.checkpoint.checkpointer import Checkpointer
            from repro.distributed import context as mesh_ctx, sharding
            from repro.ft import elastic
            from repro.launch import steps as steps_lib
            from repro.models import lm

            cfg = configs.get("tinyllama-1.1b", smoke=True)
            batch = {"inputs": jnp.zeros((4, 16), jnp.int32),
                     "labels": jnp.ones((4, 16), jnp.int32)}
            ckdir = tempfile.mkdtemp()

            mesh_a = jax.make_mesh((4, 2), ("data", "model"))
            mesh_ctx.set_mesh_axes("data", "model")
            with mesh_a:
                fn, in_sp, _, opt = steps_lib.build_train_step(
                    cfg, mesh_a, example_batch=batch)
                params = jax.device_put(
                    lm.init_params(jax.random.PRNGKey(0), cfg),
                    sharding.to_shardings(in_sp[0], mesh_a))
                opt_state = jax.device_put(opt.init(params),
                    sharding.to_shardings(in_sp[1], mesh_a))
                params, opt_state, m0 = fn(params, opt_state, batch)
                ck = Checkpointer(ckdir)
                ck.save(0, {"p": params, "o": opt_state})

            mesh_b = jax.make_mesh((2, 4), ("data", "model"))
            with mesh_b:
                fn2, in_sp2, _, opt2 = steps_lib.build_train_step(
                    cfg, mesh_b, example_batch=batch)
                like = {"p": params, "o": opt_state}
                state = elastic.remesh_restore(
                    ck, 0, like, mesh_b,
                    {"p": in_sp2[0], "o": in_sp2[1]})
                p2, o2, m1 = fn2(state["p"], state["o"], batch)
                print("LOSSES", float(m0["loss"]), float(m1["loss"]))
            """)
        l0, l1 = map(float, out.strip().split("LOSSES")[-1].split())
        assert l1 < l0 + 1.0  # continued training, no blow-up

    @pytest.mark.slow
    def test_production_dryrun_one_cell(self):
        """512-device multi-pod lower+compile for one cell end-to-end."""
        out = run_sub("""
            import sys
            sys.argv = ["dryrun", "--arch", "qwen3-1.7b", "--shape",
                        "train_4k", "--mesh", "multi", "--out",
                        "/tmp/dryrun_test"]
            from repro.launch import dryrun
            dryrun.main()
            """, timeout=900)
        assert "all dry-run cells passed" in out
