import os
import sys

# Tests run on the single host CPU device (the multi-device dry-run tests
# spawn subprocesses that set XLA_FLAGS before importing jax) — unless
# REPRO_FORCE_MESH=DxM asks for a forced-CPU mesh, in which case the whole
# tier-1 suite executes under the engine's 2D PartitionPlan (batch over
# "data", bank class rows over "model"); results are bit-identical, so the
# suite doubles as the sharded-execution regression net.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.distributed import forcemesh  # noqa: E402  (imports no jax)

# phase 1 must precede any jax backend init — conftest imports before tests
_FORCED = forcemesh.apply_xla_flags()


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running (multi-minute) integration tests")
    if _FORCED:
        forcemesh.install()
