import os
import sys

# Tests run on the single host CPU device (the multi-device dry-run tests
# spawn subprocesses that set XLA_FLAGS before importing jax).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running (multi-minute) integration tests")
